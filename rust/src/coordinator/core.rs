//! The coordinator proper: ingress queue → router → workers/batchers.
//!
//! Topology (all std threads; tokio is unavailable offline and the
//! workloads are CPU-bound anyway):
//!
//! ```text
//!  submit_*() ──bounded channel──► router thread
//!      │ (backpressure: Busy)        │
//!      │                    ┌────────┴──────────┐
//!      │              encrypted → enc-batcher   plain → batcher thread
//!      │                    (per-session group     (size/timeout policy,
//!      │                     accumulation, then     slot-model batch or
//!      │                     least-loaded worker)   Rust slot math)
//!      │                           │
//!      │                    HE worker 0..W-1
//!      │                    (own Evaluator; packed-group eval)
//!      ▼
//!  Receiver<Response>  ◄── response channels ──────┘
//! ```
//!
//! Responses travel on per-request rendezvous channels, so a caller
//! can block (`recv`) or poll (`try_recv`).
//!
//! # Encrypted-path batching
//!
//! The same [`BatchPolicy`] that drives the plaintext fast path also
//! drives the encrypted path: single-sample requests from one session
//! accumulate until the current target is held (or the oldest times
//! out), then flush as **one packed group** — the worker runs the
//! compiled **folded** schedule through the schedule engine
//! (`HrfServer::execute` with `EncRequest::group`): one evaluation
//! scores the whole group and the per-sample extraction
//! rotations are folded into the layer-3 reduction, so each caller's
//! [`EncScores`] response carries the shared per-class ciphertexts
//! plus the slot holding *its* score (`plan.score_slot(g)`) — saving
//! `C·(B−1)` key-switches per batch over the legacy eval+extract
//! path. Requires the session's Galois keys to cover
//! `HrfServer::eval_key_requirements(b)`; a session whose keys only
//! cover a smaller batch is served in the largest coverable chunks
//! (down to per-request evaluation).
//!
//! **Adaptive target** (`CoordinatorConfig::adaptive_enc_batch`): the
//! forming target starts at `enc_batch` and scales with the admitted
//! queue depth up to the plan's group capacity — the system batches
//! harder exactly when load builds, and the idle-flush grace keeps
//! latency low when it doesn't.

use super::batcher::{BatchAction, BatchPolicy};
use super::metrics::Metrics;
use super::session::SessionManager;
use crate::ckks::rns::ContextRef;
use crate::ckks::{Ciphertext, Encoder, Evaluator};
use crate::hrf::client::reshuffle_and_pack;
use crate::hrf::{EncRequest, EncScores, HrfServer};
use crate::keycache::CacheState;
use crate::lockutil::lock_unpoisoned;
use crate::obs::trace::{RequestTrace, TraceKind, TracePhase, TraceSink};
use crate::runtime::{SlotModel, SlotModelParams};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator tuning knobs.
///
/// Not `Copy`: `spill_dir` owns a path. Clone where a second copy is
/// needed.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// HE worker threads.
    pub workers: usize,
    /// Ingress queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Plaintext batch size (≤ the AOT artifact's B when the slot
    /// model is used).
    pub max_batch: usize,
    /// Max time a request may wait for batch-mates (both paths).
    pub batch_delay: Duration,
    /// Encrypted-path group size: how many single-sample requests from
    /// one session are packed into one ciphertext before a single
    /// evaluation. Clamped to the plan's group count; `1` disables
    /// server-side packing.
    pub enc_batch: usize,
    /// Scale the encrypted-path forming target with queue depth:
    /// under load the target grows from `enc_batch` toward the plan's
    /// group capacity (batch harder when it pays most), falling back
    /// to `enc_batch` when the queue drains. No effect when
    /// `enc_batch <= 1`.
    pub adaptive_enc_batch: bool,
    /// Adaptive flush: when a batcher's queue has been idle (no
    /// arrival) for this long, partial batches flush immediately
    /// instead of waiting out `batch_delay`. Batches still fill to
    /// capacity under sustained load; this only trims the latency tax
    /// when traffic pauses. Set `>= batch_delay` to disable.
    pub idle_flush: Duration,
    /// Limb-parallel worker threads *inside* each HE op
    /// (`CkksContext::set_workers`): fans per-limb loops (NTTs,
    /// element-wise kernels, key-switch inner products) across cores
    /// while `workers` scales across requests. `0` keeps the context's
    /// current setting (its `CRYPTOTREE_CKKS_WORKERS` env default).
    /// Outputs are bit-identical for every value.
    pub ckks_workers: usize,
    /// Op-parallel worker threads *per evaluation*
    /// (`HrfServer::set_op_workers`): runs independent schedule ops
    /// concurrently through the hazard-DAG driver, composing with
    /// `ckks_workers` (op-level × limb-level parallelism). `0` keeps
    /// the server's current setting (its `CRYPTOTREE_OP_WORKERS` env
    /// default). Outputs are bit-identical for every value.
    pub op_workers: usize,
    /// Span-timeline trace ring capacity (`crate::obs`): how many
    /// completed request traces `Metrics::trace` retains. `0` disables
    /// tracing entirely — requests carry inert traces and no per-
    /// request allocation or ring push happens.
    pub trace_capacity: usize,
    /// Retarget the process-wide CKKS slab pool
    /// ([`crate::mem::global_pool`]) to this many resident bytes at
    /// startup. `0` (the default) keeps the pool's current budget
    /// (its `CRYPTOTREE_SLAB_BUDGET` env default).
    pub slab_budget_bytes: u64,
    /// Enable the key-cache disk spill tier rooted at this directory
    /// ([`SessionManager::enable_spill`]): budget-evicted session keys
    /// are demoted to disk and reloaded transparently on the next
    /// lookup. `None` (the default unless `CRYPTOTREE_SPILL_DIR` is
    /// set) keeps eviction in-memory-only. The directory is wiped at
    /// startup — spilled keys never outlive the process.
    pub spill_dir: Option<PathBuf>,
    /// Byte cap for the spill directory; oldest spill files are
    /// deleted (truly evicted) once exceeded. Ignored when
    /// `spill_dir` is `None`. Defaults to `CRYPTOTREE_SPILL_BUDGET`
    /// or 1 GiB.
    pub spill_budget_bytes: u64,
}

/// Read a `u64` env knob; unset/unparsable/zero falls back.
fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .filter(|&v| v > 0)
        .unwrap_or(default)
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_capacity: 64,
            max_batch: 8,
            batch_delay: Duration::from_millis(5),
            enc_batch: 1,
            adaptive_enc_batch: true,
            idle_flush: Duration::from_millis(1),
            ckks_workers: 0,
            op_workers: 0,
            trace_capacity: 256,
            slab_budget_bytes: 0,
            spill_dir: std::env::var_os("CRYPTOTREE_SPILL_DIR")
                .filter(|v| !v.is_empty())
                .map(PathBuf::from),
            spill_budget_bytes: env_u64("CRYPTOTREE_SPILL_BUDGET", 1024 * 1024 * 1024),
        }
    }
}

/// Why a submission was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Ingress queue full — shed load upstream.
    Busy,
    /// Coordinator is shutting down.
    Closed,
    /// Unknown session id.
    NoSession,
    /// The session exists but its evaluation keys were evicted by the
    /// key cache: re-register them (same id) via
    /// [`SessionManager::reregister`] and resubmit.
    KeysEvicted,
    /// Packed batch larger than the plan's group capacity.
    BatchTooLarge,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let msg = match self {
            SubmitError::Busy => "ingress queue full (backpressure); retry after shedding load",
            SubmitError::Closed => "coordinator is shutting down",
            SubmitError::NoSession => "unknown session id; register evaluation keys first",
            SubmitError::KeysEvicted => {
                "session keys evicted from the key cache; re-register (same id) and resubmit"
            }
            SubmitError::BatchTooLarge => "packed batch exceeds the plan's group capacity",
        };
        f.write_str(msg)
    }
}

impl std::error::Error for SubmitError {}

/// Encrypted-path response: per-class score ciphertexts plus the slot
/// carrying this request's score (see [`EncScores`]; decrypt with
/// `HrfClient::decrypt_response`). Single-sample and fallback
/// responses use slot 0; folded batch responses address each caller's
/// group score slot. Packed-group submissions
/// ([`Coordinator::submit_encrypted_packed`]) return slot 0 and are
/// unpacked with `HrfClient::decrypt_scores_batch` on `.scores`.
///
/// Errors are typed: work admitted past the submission gate can still
/// fail mid-flight with [`SubmitError::KeysEvicted`] (key cache
/// evicted the session between admission and evaluation — re-register
/// and resubmit) or [`SubmitError::NoSession`] (session removed).
pub type EncResponse = Result<EncScores, SubmitError>;
/// Plaintext-path response: per-class scores.
pub type PlainResponse = Result<Vec<f64>, String>;

/// One held encrypted request: ciphertext, enqueue time, span trace,
/// reply sender.
pub(crate) struct EncItem {
    pub(crate) ct: Box<Ciphertext>,
    pub(crate) enqueued: Instant,
    pub(crate) trace: RequestTrace,
    pub(crate) resp: SyncSender<EncResponse>,
}

enum Request {
    Encrypted {
        session_id: u64,
        ct: Box<Ciphertext>,
        enqueued: Instant,
        trace: RequestTrace,
        resp: SyncSender<EncResponse>,
    },
    /// Client-side packed group: evaluated as-is; scores stay at the
    /// group score slots for `HrfClient::decrypt_scores_batch`.
    EncryptedPacked {
        session_id: u64,
        ct: Box<Ciphertext>,
        n_samples: usize,
        enqueued: Instant,
        trace: RequestTrace,
        resp: SyncSender<EncResponse>,
    },
    Plain {
        x: Vec<f64>,
        enqueued: Instant,
        trace: RequestTrace,
        resp: SyncSender<PlainResponse>,
    },
}

/// Work dispatched to an HE worker.
enum WorkerJob {
    /// A flushed group of single-sample requests from one session.
    Group { session_id: u64, items: Vec<EncItem> },
    /// A client-side packed multi-sample ciphertext.
    Packed {
        session_id: u64,
        ct: Box<Ciphertext>,
        n_samples: usize,
        enqueued: Instant,
        trace: RequestTrace,
        resp: SyncSender<EncResponse>,
    },
}

/// Outcome of [`Coordinator::shutdown`]: which serving threads (if
/// any) terminated by panic rather than by draining cleanly. A
/// serving binary should treat a non-clean report as a failed stop
/// and exit non-zero — the panics were already logged to stderr as
/// they were collected.
#[derive(Debug, Default)]
pub struct ShutdownReport {
    /// `(thread name, panic message)` for every thread that panicked.
    pub worker_panics: Vec<(String, String)>,
}

impl ShutdownReport {
    /// True when every thread exited without panicking.
    pub fn is_clean(&self) -> bool {
        self.worker_panics.is_empty()
    }
}

/// Render a captured panic payload (`JoinHandle::join`'s `Err`) as a
/// message. Panics raised via `panic!("...")` carry `&str` or
/// `String`; anything else gets a placeholder.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    ingress: SyncSender<Request>,
    pub metrics: Arc<Metrics>,
    pub sessions: Arc<SessionManager>,
    max_packed: usize,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start router, enc-batcher, HE workers and the plaintext batcher.
    ///
    /// `artifacts_dir` enables the slot-model fast path: the batcher
    /// thread loads the AOT slot model locally. When `None` — or when
    /// loading fails (e.g. shape mismatch with the packed HRF) — the
    /// plaintext path computes the identical slot model in Rust.
    pub fn start(
        cfg: CoordinatorConfig,
        ctx: ContextRef,
        server: Arc<HrfServer>,
        sessions: Arc<SessionManager>,
        artifacts_dir: Option<PathBuf>,
    ) -> Self {
        assert!(cfg.workers >= 1);
        if cfg.slab_budget_bytes > 0 {
            crate::mem::global_pool().set_budget_bytes(cfg.slab_budget_bytes);
        }
        if let Some(dir) = &cfg.spill_dir {
            // `Ok(false)` (already enabled — e.g. a restarted
            // coordinator over a shared SessionManager) is fine; only
            // an I/O failure degrades to in-memory-only eviction.
            if let Err(e) =
                sessions.enable_spill(dir.clone(), cfg.spill_budget_bytes, ctx.clone())
            {
                eprintln!(
                    "[coordinator] keycache spill tier disabled ({}): {e}",
                    dir.display()
                );
            }
        }
        if cfg.ckks_workers > 0 {
            ctx.set_workers(cfg.ckks_workers);
        }
        if cfg.op_workers > 0 {
            server.set_op_workers(cfg.op_workers);
        }
        // Pre-warm the Galois-permutation cache from the compiled
        // schedules so serving never takes the perm lock's write path.
        server.prewarm(&ctx, server.model.plan.groups);
        // Metrics share the session cache's counters so one snapshot
        // covers queueing AND key residency; the span-trace ring is
        // sized here (capacity 0 ⇒ tracing off, inert traces).
        let metrics = Arc::new(Metrics {
            trace: Arc::new(TraceSink::with_capacity(cfg.trace_capacity)),
            ..Metrics::with_keycache(sessions.keycache_stats())
        });
        let shutdown = Arc::new(AtomicBool::new(false));
        let (ingress_tx, ingress_rx) = sync_channel::<Request>(cfg.queue_capacity);
        let mut threads = Vec::new();
        let groups = server.model.plan.groups;
        let enc_batch = cfg.enc_batch.clamp(1, groups);
        metrics
            .batch_capacity
            .store(cfg.max_batch as u64, Ordering::Relaxed);
        metrics
            .enc_batch_capacity
            .store(enc_batch as u64, Ordering::Relaxed);

        // --- HE workers -------------------------------------------
        let mut worker_txs = Vec::new();
        let worker_loads: Arc<Vec<AtomicUsize>> =
            Arc::new((0..cfg.workers).map(|_| AtomicUsize::new(0)).collect());
        for w in 0..cfg.workers {
            let (tx, rx) = sync_channel::<WorkerJob>(cfg.queue_capacity);
            worker_txs.push(tx);
            let ctx = ctx.clone();
            let server = server.clone();
            let sessions = sessions.clone();
            let metrics = metrics.clone();
            let loads = worker_loads.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("hrf-worker-{w}"))
                    .spawn(move || {
                        let enc = Encoder::new(&ctx);
                        let mut ev = Evaluator::new(ctx.clone());
                        while let Ok(job) = rx.recv() {
                            match job {
                                WorkerJob::Group { session_id, items } => {
                                    run_group(
                                        &server, &sessions, &metrics, &mut ev, &enc,
                                        session_id, items,
                                    );
                                }
                                WorkerJob::Packed {
                                    session_id,
                                    ct,
                                    n_samples,
                                    enqueued,
                                    mut trace,
                                    resp,
                                } => {
                                    let exec_start = Instant::now();
                                    trace.stamp(TracePhase::Executing);
                                    let result = match sessions.get_untracked(session_id) {
                                        Some(sess) => {
                                            stamp_dag_gauges(&server, &metrics, 1);
                                            let ex = server.execute(
                                                &mut ev,
                                                &enc,
                                                &EncRequest::single(&ct),
                                                &sess.relin,
                                                &sess.galois,
                                            );
                                            // Client-side packed group:
                                            // scores stay at the group
                                            // score slots; the client
                                            // unpacks with
                                            // decrypt_scores_batch.
                                            Ok(EncScores {
                                                scores: ex.into_class_scores(),
                                                slot: 0,
                                            })
                                        }
                                        None => {
                                            Err(mid_flight_error(&sessions, session_id))
                                        }
                                    };
                                    metrics
                                        .encrypted_completed
                                        .fetch_add(n_samples as u64, Ordering::Relaxed);
                                    lock_unpoisoned(&metrics.encrypted_latency)
                                        .record(enqueued.elapsed());
                                    lock_unpoisoned(&metrics.encrypted_queue)
                                        .record(exec_start.duration_since(enqueued));
                                    lock_unpoisoned(&metrics.encrypted_service)
                                        .record(exec_start.elapsed());
                                    trace.stamp(TracePhase::Responded);
                                    metrics.trace.record(trace);
                                    let _ = resp.send(result);
                                }
                            }
                            loads[w].fetch_sub(1, Ordering::Relaxed);
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        // --- encrypted-path batcher ---------------------------------
        let (enc_tx, enc_rx) = sync_channel::<Request>(cfg.queue_capacity);
        {
            let metrics = metrics.clone();
            let loads = worker_loads.clone();
            let worker_txs = worker_txs;
            let batch_delay = cfg.batch_delay;
            let idle_flush = cfg.idle_flush;
            let adaptive = cfg.adaptive_enc_batch;
            let group_cap = groups;
            threads.push(
                std::thread::Builder::new()
                    .name("enc-batcher".into())
                    .spawn(move || {
                        let dispatch = |job: WorkerJob| {
                            let (best, _) = loads
                                .iter()
                                .enumerate()
                                .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
                                .expect("workers >= 1");
                            loads[best].fetch_add(1, Ordering::Relaxed);
                            // Blocking send: when every worker queue is
                            // full the batcher stalls, which backs
                            // pressure up through the router to callers.
                            if worker_txs[best].send(job).is_err() {
                                loads[best].fetch_sub(1, Ordering::Relaxed);
                            }
                        };
                        // Per-session forming groups.
                        struct Forming {
                            policy: BatchPolicy,
                            items: Vec<EncItem>,
                        }
                        let mut forming: HashMap<u64, Forming> = HashMap::new();
                        let flush = |sid: u64,
                                     f: &mut Forming,
                                     metrics: &Metrics,
                                     dispatch: &dyn Fn(WorkerJob)| {
                            let n = f.items.len();
                            if n == 0 {
                                return;
                            }
                            if enc_batch > 1 {
                                metrics
                                    .enc_batches_flushed
                                    .fetch_add(1, Ordering::Relaxed);
                                metrics
                                    .enc_batch_fill_sum
                                    .fetch_add(n as u64, Ordering::Relaxed);
                            }
                            // One flush id per dispatched group: every
                            // trace flushed together shares it, so a
                            // timeline dump shows exactly which requests
                            // rode the same packed evaluation.
                            let fid = metrics.trace.next_flush_id();
                            for it in f.items.iter_mut() {
                                it.trace.stamp_batched(fid, n as u32);
                            }
                            dispatch(WorkerJob::Group {
                                session_id: sid,
                                items: std::mem::take(&mut f.items),
                            });
                            f.policy.on_flush(n);
                        };
                        loop {
                            let deadline = forming
                                .values()
                                .filter_map(|f| f.policy.deadline())
                                .min();
                            let mut timeout = deadline
                                .map(|d| d.saturating_duration_since(Instant::now()))
                                .unwrap_or(Duration::from_millis(50));
                            // Adaptive batching: while groups are
                            // forming, wait only a short idle grace for
                            // the next arrival — a quiet queue flushes
                            // partial groups immediately instead of
                            // sitting out batch_delay.
                            let forming_any =
                                forming.values().any(|f| !f.items.is_empty());
                            if forming_any {
                                timeout = timeout.min(idle_flush);
                            }
                            match enc_rx.recv_timeout(timeout) {
                                Ok(Request::Encrypted {
                                    session_id,
                                    ct,
                                    enqueued,
                                    mut trace,
                                    resp,
                                }) => {
                                    metrics
                                        .enc_queue_depth
                                        .fetch_sub(1, Ordering::Relaxed);
                                    if enc_batch <= 1 {
                                        // Unbatched: still a flush of one,
                                        // so timelines stay comparable.
                                        trace.stamp_batched(
                                            metrics.trace.next_flush_id(),
                                            1,
                                        );
                                        dispatch(WorkerJob::Group {
                                            session_id,
                                            items: vec![EncItem {
                                                ct,
                                                enqueued,
                                                trace,
                                                resp,
                                            }],
                                        });
                                    } else {
                                        let f = forming.entry(session_id).or_insert_with(
                                            || Forming {
                                                policy: BatchPolicy::new(
                                                    enc_batch,
                                                    batch_delay,
                                                ),
                                                items: Vec::new(),
                                            },
                                        );
                                        // Adaptive batching: the
                                        // forming target tracks queue
                                        // depth — batch harder while
                                        // work is stacking up, revert
                                        // to the configured base when
                                        // it drains.
                                        if adaptive {
                                            let depth = metrics
                                                .enc_queue_depth
                                                .load(Ordering::Relaxed)
                                                as usize;
                                            f.policy.set_max_batch(
                                                (enc_batch + depth).min(group_cap),
                                            );
                                        }
                                        f.items.push(EncItem {
                                            ct,
                                            enqueued,
                                            trace,
                                            resp,
                                        });
                                        if f.policy.on_arrival(Instant::now())
                                            == BatchAction::Flush
                                        {
                                            flush(session_id, f, &metrics, &dispatch);
                                        }
                                    }
                                }
                                Ok(Request::EncryptedPacked {
                                    session_id,
                                    ct,
                                    n_samples,
                                    enqueued,
                                    trace,
                                    resp,
                                }) => {
                                    metrics
                                        .enc_queue_depth
                                        .fetch_sub(1, Ordering::Relaxed);
                                    // Packed groups bypass server-side
                                    // forming, so their timelines skip
                                    // the `Batched` phase by design.
                                    dispatch(WorkerJob::Packed {
                                        session_id,
                                        ct,
                                        n_samples,
                                        enqueued,
                                        trace,
                                        resp,
                                    });
                                }
                                Ok(Request::Plain { .. }) => {
                                    unreachable!("router sends only encrypted here")
                                }
                                Err(RecvTimeoutError::Timeout) => {
                                    // Queue idle (or a deadline hit):
                                    // ship every partial group now.
                                    let sids: Vec<u64> =
                                        forming.keys().copied().collect();
                                    for sid in sids {
                                        if let Some(f) = forming.get_mut(&sid) {
                                            flush(sid, f, &metrics, &dispatch);
                                        }
                                    }
                                }
                                Err(RecvTimeoutError::Disconnected) => {
                                    let sids: Vec<u64> = forming.keys().copied().collect();
                                    for sid in sids {
                                        if let Some(f) = forming.get_mut(&sid) {
                                            flush(sid, f, &metrics, &dispatch);
                                        }
                                    }
                                    break;
                                }
                            }
                            // Timed-out partial batches are checked on EVERY
                            // iteration — not only when the channel goes
                            // quiet — so a held request's extra latency is
                            // bounded by batch_delay even under a steady
                            // stream of other sessions' traffic. Flushed
                            // (empty) sessions are evicted to keep this scan
                            // and the map itself bounded by *active* sessions.
                            let now = Instant::now();
                            let mut due = Vec::new();
                            for (sid, f) in forming.iter_mut() {
                                if f.policy.on_tick(now) == BatchAction::Flush {
                                    due.push(*sid);
                                }
                            }
                            for sid in due {
                                if let Some(f) = forming.get_mut(&sid) {
                                    flush(sid, f, &metrics, &dispatch);
                                }
                            }
                            forming.retain(|_, f| !f.items.is_empty());
                        }
                    })
                    .expect("spawn enc-batcher"),
            );
        }

        // --- plaintext batcher --------------------------------------
        let (batch_tx, batch_rx) = sync_channel::<Request>(cfg.queue_capacity);
        {
            let server = server.clone();
            let metrics = metrics.clone();
            let cfg_b = cfg;
            threads.push(
                std::thread::Builder::new()
                    .name("plain-batcher".into())
                    .spawn(move || {
                        // Slot-model fast path, loaded on this thread only.
                        let slot_model: Option<(SlotModel, SlotModelParams)> =
                            artifacts_dir.and_then(|dir| {
                                match SlotModel::load(&dir) {
                                    Ok(sm) => {
                                        match SlotModelParams::from_hrf(&server.model, sm.shape)
                                        {
                                            Ok(p) => Some((sm, p)),
                                            Err(e) => {
                                                eprintln!(
                                                    "[batcher] slot-model params mismatch ({e}); using Rust slot math"
                                                );
                                                None
                                            }
                                        }
                                    }
                                    Err(e) => {
                                        eprintln!(
                                            "[batcher] slot-model load failed ({e}); using Rust slot math"
                                        );
                                        None
                                    }
                                }
                            });
                        type PlainHeld =
                            (Vec<f64>, Instant, RequestTrace, SyncSender<PlainResponse>);
                        let mut policy = BatchPolicy::new(cfg_b.max_batch, cfg_b.batch_delay);
                        let mut held: Vec<PlainHeld> = Vec::new();
                        let flush = |held: &mut Vec<PlainHeld>| {
                            if held.is_empty() {
                                return 0usize;
                            }
                            let n = held.len();
                            // The whole batch shares one flush id and one
                            // execution start; slot-model inference is a
                            // single call over all n inputs.
                            let fid = metrics.trace.next_flush_id();
                            let exec_start = Instant::now();
                            for (_, _, trace, _) in held.iter_mut() {
                                trace.stamp_batched(fid, n as u32);
                                trace.stamp(TracePhase::Executing);
                            }
                            let slot_inputs: Vec<Vec<f32>> = held
                                .iter()
                                .map(|(x, _, _, _)| {
                                    reshuffle_and_pack(&server.model, x)
                                        .iter()
                                        .map(|&v| v as f32)
                                        .collect()
                                })
                                .collect();
                            // Slot-model fast path, Rust slot math fallback.
                            let scores: Vec<Vec<f64>> = match &slot_model {
                                Some(sm) => match sm.0.infer_batch(&slot_inputs, &sm.1) {
                                    Ok(rows) => rows
                                        .into_iter()
                                        .map(|r| r.iter().map(|&v| v as f64).collect())
                                        .collect(),
                                    Err(e) => {
                                        for (_, _, mut trace, resp) in held.drain(..) {
                                            trace.stamp(TracePhase::Responded);
                                            metrics.trace.record(trace);
                                            let _ = resp.send(Err(format!("slot model: {e}")));
                                        }
                                        return n;
                                    }
                                },
                                None => held
                                    .iter()
                                    .map(|(x, _, _, _)| {
                                        let slots = reshuffle_and_pack(&server.model, x);
                                        server.model.forward_slots_plain(&slots)
                                    })
                                    .collect(),
                            };
                            // Batch accounting first: a caller that has
                            // received its response must already see the
                            // flush reflected in the metrics.
                            metrics.batches_flushed.fetch_add(1, Ordering::Relaxed);
                            metrics
                                .batch_fill_sum
                                .fetch_add(n as u64, Ordering::Relaxed);
                            for ((_, enq, mut trace, resp), s) in held.drain(..).zip(scores) {
                                metrics.plain_completed.fetch_add(1, Ordering::Relaxed);
                                lock_unpoisoned(&metrics.plain_latency).record(enq.elapsed());
                                lock_unpoisoned(&metrics.plain_queue)
                                    .record(exec_start.duration_since(enq));
                                lock_unpoisoned(&metrics.plain_service)
                                    .record(exec_start.elapsed());
                                trace.stamp(TracePhase::Responded);
                                metrics.trace.record(trace);
                                let _ = resp.send(Ok(s));
                            }
                            n
                        };
                        loop {
                            let mut timeout = policy
                                .deadline()
                                .map(|d| d.saturating_duration_since(Instant::now()))
                                .unwrap_or(Duration::from_millis(50));
                            // Adaptive batching (see the enc-batcher):
                            // a quiet queue flushes the partial batch
                            // after a short idle grace.
                            if !held.is_empty() {
                                timeout = timeout.min(cfg_b.idle_flush);
                            }
                            match batch_rx.recv_timeout(timeout) {
                                Ok(Request::Plain {
                                    x,
                                    enqueued,
                                    trace,
                                    resp,
                                }) => {
                                    held.push((x, enqueued, trace, resp));
                                    if policy.on_arrival(Instant::now()) == BatchAction::Flush {
                                        let n = flush(&mut held);
                                        policy.on_flush(n);
                                    }
                                }
                                Ok(_) => unreachable!("router sends only Plain here"),
                                Err(RecvTimeoutError::Timeout) => {
                                    // Queue idle or deadline hit.
                                    let n = flush(&mut held);
                                    policy.on_flush(n);
                                }
                                Err(RecvTimeoutError::Disconnected) => {
                                    let n = flush(&mut held);
                                    policy.on_flush(n);
                                    break;
                                }
                            }
                        }
                    })
                    .expect("spawn batcher"),
            );
        }

        // --- router --------------------------------------------------
        {
            threads.push(
                std::thread::Builder::new()
                    .name("router".into())
                    .spawn(move || {
                        while let Ok(req) = ingress_rx.recv() {
                            match req {
                                enc @ (Request::Encrypted { .. }
                                | Request::EncryptedPacked { .. }) => {
                                    let _ = enc_tx.send(enc);
                                }
                                plain @ Request::Plain { .. } => {
                                    let _ = batch_tx.send(plain);
                                }
                            }
                        }
                        // ingress closed: drop enc-batcher/batcher
                        // senders so their loops terminate (and they
                        // drop the worker senders in turn).
                    })
                    .expect("spawn router"),
            );
        }

        Coordinator {
            ingress: ingress_tx,
            metrics,
            sessions,
            max_packed: groups,
            shutdown,
            threads,
        }
    }

    /// Submit an encrypted inference (one observation packed in sample
    /// group 0 — the `HrfClient::encrypt_input` layout). Fails fast on
    /// backpressure, a missing session, or evicted keys (all checked
    /// before queueing; the resident-key check also refreshes the
    /// session's LRU stamp so queued work keeps its keys hot).
    pub fn submit_encrypted(
        &self,
        session_id: u64,
        ct: Ciphertext,
    ) -> Result<Receiver<EncResponse>, SubmitError> {
        let trace = self.metrics.trace.begin(TraceKind::Encrypted);
        self.submit_encrypted_traced(session_id, ct, trace)
    }

    /// [`submit_encrypted`](Self::submit_encrypted) carrying a span
    /// trace started upstream (the net server begins it at socket
    /// accept so the timeline covers decode time too). The trace is
    /// dropped — never recorded — when the submission is rejected.
    pub fn submit_encrypted_traced(
        &self,
        session_id: u64,
        ct: Ciphertext,
        mut trace: RequestTrace,
    ) -> Result<Receiver<EncResponse>, SubmitError> {
        if self.shutdown.load(Ordering::Relaxed) {
            return Err(SubmitError::Closed);
        }
        self.check_session(session_id)?;
        trace.stamp(TracePhase::Admitted);
        let (resp_tx, resp_rx) = sync_channel(1);
        let req = Request::Encrypted {
            session_id,
            ct: Box::new(ct),
            enqueued: Instant::now(),
            trace,
            resp: resp_tx,
        };
        // Gauge up BEFORE the request becomes visible to the batcher
        // (its decrement must never observe a pre-increment count).
        self.metrics.enc_queue_depth.fetch_add(1, Ordering::Relaxed);
        match self.try_enqueue(req, resp_rx) {
            Ok(rx) => Ok(rx),
            Err(e) => {
                self.metrics.enc_queue_depth.fetch_sub(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit a client-side packed group of `n_samples ≤ plan.groups`
    /// observations (the `HrfClient::encrypt_batch` layout). The
    /// response's per-class ciphertexts carry sample `g`'s score at
    /// `plan.score_slot(g)`; unpack with
    /// `HrfClient::decrypt_scores_batch`.
    pub fn submit_encrypted_packed(
        &self,
        session_id: u64,
        ct: Ciphertext,
        n_samples: usize,
    ) -> Result<Receiver<EncResponse>, SubmitError> {
        let trace = self.metrics.trace.begin(TraceKind::Packed);
        self.submit_encrypted_packed_traced(session_id, ct, n_samples, trace)
    }

    /// [`submit_encrypted_packed`](Self::submit_encrypted_packed) with
    /// an upstream-started span trace (see
    /// [`submit_encrypted_traced`](Self::submit_encrypted_traced)).
    pub fn submit_encrypted_packed_traced(
        &self,
        session_id: u64,
        ct: Ciphertext,
        n_samples: usize,
        mut trace: RequestTrace,
    ) -> Result<Receiver<EncResponse>, SubmitError> {
        if self.shutdown.load(Ordering::Relaxed) {
            return Err(SubmitError::Closed);
        }
        if n_samples == 0 || n_samples > self.max_packed {
            return Err(SubmitError::BatchTooLarge);
        }
        self.check_session(session_id)?;
        trace.stamp(TracePhase::Admitted);
        let (resp_tx, resp_rx) = sync_channel(1);
        let req = Request::EncryptedPacked {
            session_id,
            ct: Box::new(ct),
            n_samples,
            enqueued: Instant::now(),
            trace,
            resp: resp_tx,
        };
        // See submit_encrypted: gauge up before enqueue, roll back on
        // rejection.
        self.metrics.enc_queue_depth.fetch_add(1, Ordering::Relaxed);
        match self.try_enqueue(req, resp_rx) {
            Ok(rx) => Ok(rx),
            Err(e) => {
                self.metrics.enc_queue_depth.fetch_sub(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit a plaintext inference (features, not slots).
    pub fn submit_plain(&self, x: Vec<f64>) -> Result<Receiver<PlainResponse>, SubmitError> {
        let trace = self.metrics.trace.begin(TraceKind::Plain);
        self.submit_plain_traced(x, trace)
    }

    /// [`submit_plain`](Self::submit_plain) with an upstream-started
    /// span trace (see
    /// [`submit_encrypted_traced`](Self::submit_encrypted_traced)).
    pub fn submit_plain_traced(
        &self,
        x: Vec<f64>,
        mut trace: RequestTrace,
    ) -> Result<Receiver<PlainResponse>, SubmitError> {
        if self.shutdown.load(Ordering::Relaxed) {
            return Err(SubmitError::Closed);
        }
        trace.stamp(TracePhase::Admitted);
        let (resp_tx, resp_rx) = sync_channel(1);
        let req = Request::Plain {
            x,
            enqueued: Instant::now(),
            trace,
            resp: resp_tx,
        };
        self.try_enqueue(req, resp_rx)
    }

    /// Gate a submission on the session's key-cache state (the
    /// eviction-safe protocol's server half). `lookup` already
    /// promotes spilled keys back to residency, so `Evicted` here
    /// means the spill tier (if any) could not help either.
    fn check_session(&self, session_id: u64) -> Result<(), SubmitError> {
        match self.sessions.lookup(session_id) {
            CacheState::Resident(_) => Ok(()),
            // `lookup` never returns `Spilled` (it reloads instead),
            // but admit defensively if that ever changes: the worker
            // will promote on its own lookup.
            CacheState::Spilled => Ok(()),
            CacheState::Evicted => {
                self.metrics
                    .rejected_keys_evicted
                    .fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::KeysEvicted)
            }
            CacheState::Unknown => {
                self.metrics
                    .rejected_no_session
                    .fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::NoSession)
            }
        }
    }

    fn try_enqueue<T>(
        &self,
        req: Request,
        resp_rx: Receiver<T>,
    ) -> Result<Receiver<T>, SubmitError> {
        match self.ingress.try_send(req) {
            Ok(()) => Ok(resp_rx),
            Err(TrySendError::Full(_)) => {
                self.metrics
                    .rejected_backpressure
                    .fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Drain and stop all threads, reporting any that died by panic.
    ///
    /// A panicking worker no longer disappears silently: its payload
    /// is captured from `join`, logged to stderr, and surfaced in the
    /// returned [`ShutdownReport`] so a serving binary can exit
    /// non-zero instead of reporting a clean stop.
    pub fn shutdown(mut self) -> ShutdownReport {
        self.shutdown.store(true, Ordering::Relaxed);
        // Dropping the ingress sender unblocks the router, which drops
        // enc-batcher/batcher senders in turn.
        drop(std::mem::replace(&mut self.ingress, {
            let (tx, _rx) = sync_channel(1);
            tx
        }));
        let mut report = ShutdownReport::default();
        for t in self.threads.drain(..) {
            let name = t.thread().name().unwrap_or("<unnamed>").to_string();
            if let Err(payload) = t.join() {
                let msg = panic_message(payload.as_ref());
                eprintln!("[coordinator] thread `{name}` panicked: {msg}");
                report.worker_panics.push((name, msg));
            }
        }
        report
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

/// Evaluate one flushed group of single-sample requests on a worker.
///
/// Packed-group evaluation needs (a) a live session whose Galois keys
/// cover the folded schedule's rotations and (b) ciphertexts at a
/// uniform (level, scale). The group is served in the **largest
/// chunks the session's keys cover** (the adaptive target can exceed
/// the key set a client generated for the configured `enc_batch`);
/// nonuniform or uncoverable work degrades to per-request evaluation.
/// Each packed chunk is one `HrfServer::execute` of the folded
/// schedule — no extraction rotations; caller `g` receives the shared
/// per-class ciphertexts and its score slot.
fn run_group(
    server: &HrfServer,
    sessions: &SessionManager,
    metrics: &Metrics,
    ev: &mut Evaluator,
    enc: &Encoder,
    session_id: u64,
    items: Vec<EncItem>,
) {
    run_group_with(
        server, sessions, metrics, ev, enc, session_id, items, &mut |_| {},
    );
}

/// Classify a mid-flight session miss: the key cache distinguishes
/// *evicted* (recoverable — re-register the same id) from *unknown*
/// (session removed). A race where the keys came back between the
/// fetch and this probe still reports `KeysEvicted`, whose recovery
/// (resubmit) is exactly right.
fn mid_flight_error(sessions: &SessionManager, session_id: u64) -> SubmitError {
    match sessions.peek(session_id) {
        CacheState::Unknown => SubmitError::NoSession,
        // `Spilled` mid-flight still means the worker's own lookup
        // failed to promote in time — surface as the retryable error.
        CacheState::Evicted | CacheState::Spilled | CacheState::Resident(_) => {
            SubmitError::KeysEvicted
        }
    }
}

/// Stamp the schedule-DAG shape gauges (`Metrics::dag_ops` /
/// `dag_waves` / `dag_width`) for the evaluation about to run. No-op
/// when the server executes ops serially, so the gauges stay 0 and the
/// DAG cache is never touched unless op-parallelism is on.
pub(crate) fn stamp_dag_gauges(server: &HrfServer, metrics: &Metrics, b: usize) {
    if server.op_workers() > 1 {
        let stats = server.dag_stats(b, true);
        metrics.dag_ops.store(stats.ops as u64, Ordering::Relaxed);
        metrics.dag_waves.store(stats.waves as u64, Ordering::Relaxed);
        metrics.dag_width.store(stats.width as u64, Ordering::Relaxed);
    }
}

/// [`run_group`] with a test seam: `after_chunk(i)` runs after chunk
/// (or per-request evaluation) `i` completes, letting tests mutate
/// key-cache state between chunks deterministically.
pub(crate) fn run_group_with(
    server: &HrfServer,
    sessions: &SessionManager,
    metrics: &Metrics,
    ev: &mut Evaluator,
    enc: &Encoder,
    session_id: u64,
    items: Vec<EncItem>,
    after_chunk: &mut dyn FnMut(usize),
) {
    // Untracked fetch: the submission gate already counted this
    // request's cache hit.
    // Completion bookkeeping shared by every exit path: counters,
    // end-to-end latency, the queue/service split (when the request
    // reached an execution start) and the span-trace record.
    let complete = |metrics: &Metrics,
                    enqueued: Instant,
                    exec_start: Option<Instant>,
                    mut trace: RequestTrace,
                    resp: SyncSender<EncResponse>,
                    result: EncResponse| {
        metrics.encrypted_completed.fetch_add(1, Ordering::Relaxed);
        lock_unpoisoned(&metrics.encrypted_latency).record(enqueued.elapsed());
        if let Some(t0) = exec_start {
            lock_unpoisoned(&metrics.encrypted_queue).record(t0.duration_since(enqueued));
            lock_unpoisoned(&metrics.encrypted_service).record(t0.elapsed());
        }
        trace.stamp(TracePhase::Responded);
        metrics.trace.record(trace);
        let _ = resp.send(result);
    };
    let sess = match sessions.get_untracked(session_id) {
        Some(s) => s,
        None => {
            let err = mid_flight_error(sessions, session_id);
            for it in items {
                complete(metrics, it.enqueued, None, it.trace, it.resp, Err(err));
            }
            return;
        }
    };
    // Re-probe key residency before evaluating a chunk past the first.
    // The group can span many chunks (the adaptive target can exceed
    // the key coverage a client generated for), and the cache may
    // evict this session between chunks; the *remaining* requests then
    // fail individually with a typed, recoverable error instead of the
    // whole group being abandoned.
    let still_resident = |failed: &mut Option<SubmitError>| {
        if failed.is_none() {
            // `Spilled` keeps serving: this evaluation already holds
            // the session `Arc`, and the next lookup promotes the
            // keys back from disk.
            if let CacheState::Evicted | CacheState::Unknown = sessions.peek(session_id) {
                *failed = Some(mid_flight_error(sessions, session_id));
            }
        }
    };
    let uniform = items.windows(2).all(|w| {
        w[0].ct.level == w[1].ct.level && (w[0].ct.scale - w[1].ct.scale).abs() < 1e-6
    });
    // Largest batch size the session's Galois keys cover (can_batch is
    // monotone: the step set only grows with b).
    let mut max_b = 1usize;
    if items.len() > 1 && uniform {
        for b in (2..=items.len().min(server.model.plan.groups)).rev() {
            if server.can_batch(&sess.galois, b) {
                max_b = b;
                break;
            }
        }
    }
    let mut failed: Option<SubmitError> = None;
    if max_b > 1 {
        // Move the ciphertexts out (no deep clones on the hot path);
        // only the (enqueue time, trace, reply sender) metadata is
        // needed after the evaluation.
        type Meta = (Instant, RequestTrace, SyncSender<EncResponse>);
        let (cts, meta): (Vec<Ciphertext>, Vec<Meta>) = items
            .into_iter()
            .map(|it| (*it.ct, (it.enqueued, it.trace, it.resp)))
            .unzip();
        for (i, (chunk_cts, chunk_meta)) in
            cts.chunks(max_b).zip(meta.chunks(max_b)).enumerate()
        {
            if i > 0 {
                still_resident(&mut failed);
            }
            let mut metas: Vec<Meta> = chunk_meta.to_vec();
            if let Some(err) = failed {
                for (enqueued, trace, resp) in metas {
                    complete(metrics, enqueued, None, trace, resp, Err(err));
                }
                continue;
            }
            let exec_start = Instant::now();
            for (_, trace, _) in metas.iter_mut() {
                trace.stamp(TracePhase::Executing);
            }
            // One engine execution per chunk (a 1-chunk normalizes to
            // the single-sample folded schedule); each caller's
            // response carries the shared per-class ciphertexts plus
            // its own score slot.
            stamp_dag_gauges(server, metrics, chunk_cts.len());
            let responses = server
                .execute(ev, enc, &EncRequest::group(chunk_cts), &sess.relin, &sess.galois)
                .into_responses();
            for ((enqueued, trace, resp), r) in metas.into_iter().zip(responses) {
                complete(metrics, enqueued, Some(exec_start), trace, resp, Ok(r));
            }
            after_chunk(i);
        }
    } else {
        for (i, item) in items.into_iter().enumerate() {
            let EncItem {
                ct,
                enqueued,
                mut trace,
                resp,
            } = item;
            if i > 0 {
                still_resident(&mut failed);
            }
            if let Some(err) = failed {
                complete(metrics, enqueued, None, trace, resp, Err(err));
                continue;
            }
            let exec_start = Instant::now();
            trace.stamp(TracePhase::Executing);
            stamp_dag_gauges(server, metrics, 1);
            let r = server
                .execute(ev, enc, &EncRequest::single(&ct), &sess.relin, &sess.galois)
                .into_responses()
                .pop()
                .expect("single-sample execution yields one response");
            complete(metrics, enqueued, Some(exec_start), trace, resp, Ok(r));
            after_chunk(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::rns::CkksContext;
    use crate::ckks::{CkksParams, Encryptor, KeyGenerator};
    use crate::data::adult;
    use crate::forest::tree::TreeConfig;
    use crate::forest::{RandomForest, RandomForestConfig};
    use crate::hrf::HrfModel;
    use crate::keycache::KeyCacheConfig;
    use crate::nrf::activation::Activation;
    use crate::nrf::NeuralForest;

    /// Regression: a key-cache eviction between the chunks of one
    /// flushed group must fail the *remaining* requests with the
    /// typed, recoverable `KeysEvicted` — not abandon the group, and
    /// not serve chunks past the eviction.
    #[test]
    fn mid_chunk_eviction_fails_remaining_requests_typed() {
        // Cheap ring (N=4096, depth 4) + identity activation: the
        // chunking protocol is under test, not the numerics.
        let params = Arc::new(CkksParams::build(
            "evict-midchunk-n4096-d4",
            4096,
            60,
            40,
            4,
            3.2,
        ));
        let ctx = CkksContext::new(params.clone());
        let enc = Encoder::new(&ctx);
        let ds = adult::generate(200, 615);
        let rf = RandomForest::fit(
            &ds,
            &RandomForestConfig {
                n_trees: 4,
                tree: TreeConfig {
                    max_depth: 2,
                    ..Default::default()
                },
                ..Default::default()
            },
            616,
        );
        let nf = NeuralForest::from_forest(
            &rf,
            Activation::Poly {
                coeffs: vec![0.0, 1.0],
            },
        );
        let model = HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
        let server = HrfServer::new(model);

        let mut kg = KeyGenerator::new(&ctx, 617);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        // Keys covering exactly 2-sample chunks: the 4-item group
        // below is then served as two chunks of two.
        let gk = kg.gen_galois_keys(&ctx, &server.eval_key_requirements(2));
        assert!(server.can_batch(&gk, 2));
        assert!(
            !server.can_batch(&gk, 4) && !server.can_batch(&gk, 3),
            "test premise: b=2 keys must not cover larger chunks \
             (placement steps grow with b)"
        );
        let mut encryptor = Encryptor::new(pk, 618);

        // Budget fits one session (plus slack), not two — the second
        // registration inside the seam callback evicts the first.
        let session_bytes = (rlk.key_bytes() + gk.key_bytes()) as u64;
        let sessions = Arc::new(SessionManager::with_config(KeyCacheConfig {
            num_shards: 1,
            budget_bytes: session_bytes * 3 / 2,
        }));
        let sid = sessions.register(rlk.clone(), gk.clone());

        let metrics = Metrics::default();
        let mut ev = Evaluator::new(ctx.clone());
        let mut items: Vec<EncItem> = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..4 {
            let slots = reshuffle_and_pack(&server.model, &ds.x[i]);
            let ct = encryptor.encrypt_slots(&ctx, &enc, &slots);
            let (tx, rx) = sync_channel(1);
            items.push(EncItem {
                ct: Box::new(ct),
                enqueued: Instant::now(),
                trace: RequestTrace::inert(),
                resp: tx,
            });
            rxs.push(rx);
        }

        let sessions_cb = sessions.clone();
        let mut evicted_after = Vec::new();
        run_group_with(
            &server,
            &sessions,
            &metrics,
            &mut ev,
            &enc,
            sid,
            items,
            &mut |chunk| {
                if chunk == 0 {
                    sessions_cb.register(rlk.clone(), gk.clone());
                    assert!(
                        matches!(sessions_cb.peek(sid), CacheState::Evicted),
                        "budget pressure must evict the serving session"
                    );
                    evicted_after.push(chunk);
                }
            },
        );
        assert_eq!(evicted_after, vec![0], "seam must fire after chunk 0 only");

        // Chunk 0 (requests 0, 1) was served before the eviction …
        for rx in &rxs[..2] {
            let resp = rx.try_recv().expect("chunk-0 response missing");
            assert!(resp.is_ok(), "pre-eviction request failed: {resp:?}");
        }
        // … and chunk 1 (requests 2, 3) fails per-request with the
        // typed, recoverable error.
        for rx in &rxs[2..] {
            let resp = rx.try_recv().expect("chunk-1 response missing");
            assert_eq!(resp.err(), Some(SubmitError::KeysEvicted));
        }
        // Every request completed (metrics see all four).
        assert_eq!(metrics.encrypted_completed.load(Ordering::Relaxed), 4);
    }
}
