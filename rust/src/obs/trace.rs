//! Request-scoped span timelines.
//!
//! A [`RequestTrace`] rides along with one serving-tier request and
//! collects phase timestamps (µs offsets from the trace's start) as
//! the request moves accepted → decoded → admitted → batched →
//! executing → responded. Completed traces land in a [`TraceSink`] —
//! a fixed-capacity ring buffer behind one short mutex push per
//! request — and can be drained as [`TraceRecord`] snapshots (in
//! process via `Metrics::trace`, over the wire via the
//! `Request::TraceDump` frame).
//!
//! Cost model: a sink built with capacity 0 is *disabled* and hands
//! out inert traces — no allocation, every stamp is a `None` branch.
//! An enabled sink allocates one small heap box per request and takes
//! the ring lock exactly once, at completion; phase stamps themselves
//! touch only the request-owned box and never synchronize.

use crate::lockutil::lock_unpoisoned;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Number of [`TracePhase`] variants (length of the stamp array).
pub const N_PHASES: usize = 6;

/// One point in a request's lifecycle. Offsets are stamped in the
/// order listed; a phase a request never reaches stays `None`.
///
/// Who stamps what: the net server stamps `Accepted` (first byte of
/// the frame on the socket) and `Decoded`; the coordinator stamps
/// `Admitted` (passed the session/backpressure gate), `Batched` (the
/// batcher flushed the group it joined) and `Executing`; the worker
/// stamps `Responded` when the response is handed back. Requests
/// submitted in-process (no wire) start at `Admitted`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TracePhase {
    /// First byte of the request frame arrived on the socket.
    Accepted,
    /// Frame decoded into a typed `Request`.
    Decoded,
    /// Passed the session + backpressure gate into the ingress queue.
    Admitted,
    /// The batcher flushed the group this request joined.
    Batched,
    /// A worker began evaluating the request's chunk.
    Executing,
    /// The response was handed back toward the client.
    Responded,
}

impl TracePhase {
    /// All phases, in lifecycle order.
    pub const ALL: [TracePhase; N_PHASES] = [
        TracePhase::Accepted,
        TracePhase::Decoded,
        TracePhase::Admitted,
        TracePhase::Batched,
        TracePhase::Executing,
        TracePhase::Responded,
    ];

    /// Index into a [`TraceRecord`]'s stamp array.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Stable lower-case name (wire docs, JSON, tables).
    pub fn name(self) -> &'static str {
        match self {
            TracePhase::Accepted => "accepted",
            TracePhase::Decoded => "decoded",
            TracePhase::Admitted => "admitted",
            TracePhase::Batched => "batched",
            TracePhase::Executing => "executing",
            TracePhase::Responded => "responded",
        }
    }
}

/// Which serving path a trace belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// One encrypted sample (`submit_encrypted`).
    Encrypted,
    /// A client-packed multi-sample ciphertext
    /// (`submit_encrypted_packed`); skips the `Batched` phase — it
    /// arrives pre-batched and goes straight to a worker.
    Packed,
    /// A plaintext-feature request (`submit_plain`).
    Plain,
}

impl TraceKind {
    /// Stable lower-case name (JSON, tables).
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Encrypted => "encrypted",
            TraceKind::Packed => "packed",
            TraceKind::Plain => "plain",
        }
    }
}

#[derive(Clone, Debug)]
struct TraceData {
    id: u64,
    kind: TraceKind,
    start: Instant,
    phases: [Option<u64>; N_PHASES],
    flush: Option<(u64, u32)>,
}

/// A live trace carried by one in-flight request.
///
/// The default value is *inert*: stamps are no-ops and
/// [`TraceSink::record`] discards it. Inert traces are what a
/// disabled sink hands out, so tracing costs nothing when off.
#[derive(Clone, Debug, Default)]
pub struct RequestTrace(Option<Box<TraceData>>);

impl RequestTrace {
    /// A trace that records nothing (what a disabled sink hands out).
    pub fn inert() -> Self {
        RequestTrace(None)
    }

    /// `false` for inert traces.
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }

    /// The trace id, if active.
    pub fn id(&self) -> Option<u64> {
        self.0.as_ref().map(|d| d.id)
    }

    /// Stamp `phase` at now − start. First stamp wins: re-stamping a
    /// phase (e.g. `Executing` for each chunk of a split group) keeps
    /// the earliest timestamp.
    pub fn stamp(&mut self, phase: TracePhase) {
        if let Some(d) = &mut self.0 {
            let slot = &mut d.phases[phase.index()];
            if slot.is_none() {
                *slot = Some(d.start.elapsed().as_micros() as u64);
            }
        }
    }

    /// Stamp [`TracePhase::Batched`] and record which flush group this
    /// request shared (`flush_id` is sink-unique; `group` is how many
    /// requests the flush carried).
    pub fn stamp_batched(&mut self, flush_id: u64, group: u32) {
        self.stamp(TracePhase::Batched);
        if let Some(d) = &mut self.0 {
            if d.flush.is_none() {
                d.flush = Some((flush_id, group));
            }
        }
    }
}

/// A completed, immutable trace as drained from the sink.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceRecord {
    /// Sink-unique, monotonically increasing id.
    pub id: u64,
    /// Which serving path the request took.
    pub kind: TraceKind,
    /// `(flush_id, group_size)` of the batch flush this request rode,
    /// if it went through a batcher. Records sharing a `flush_id`
    /// shared one flush.
    pub flush: Option<(u64, u32)>,
    /// Phase offsets in µs from trace start, indexed by
    /// [`TracePhase::index`].
    pub phases: [Option<u64>; N_PHASES],
}

impl TraceRecord {
    /// Offset of `phase` from trace start, if stamped.
    pub fn phase(&self, phase: TracePhase) -> Option<Duration> {
        self.phases[phase.index()].map(Duration::from_micros)
    }

    /// Time spent queued: admitted → executing.
    pub fn queue_time(&self) -> Option<Duration> {
        self.span(TracePhase::Admitted, TracePhase::Executing)
    }

    /// Time spent evaluating: executing → responded.
    pub fn service_time(&self) -> Option<Duration> {
        self.span(TracePhase::Executing, TracePhase::Responded)
    }

    fn span(&self, from: TracePhase, to: TracePhase) -> Option<Duration> {
        let a = self.phases[from.index()]?;
        let b = self.phases[to.index()]?;
        Some(Duration::from_micros(b.saturating_sub(a)))
    }
}

#[derive(Debug, Default)]
struct Ring {
    buf: Vec<Option<TraceRecord>>,
    /// Total records ever written; `head % capacity` is the next slot.
    head: u64,
}

/// Fixed-capacity ring buffer of completed request traces.
///
/// Writers ([`record`](TraceSink::record)) take the ring mutex for one
/// slot write; the write cursor advances under the same lock, so
/// concurrent completions cannot lose an update (total records written
/// always equals the cursor). When the ring is full the oldest record
/// is overwritten and counted in [`dropped`](TraceSink::dropped).
#[derive(Debug, Default)]
pub struct TraceSink {
    capacity: usize,
    next_id: AtomicU64,
    next_flush: AtomicU64,
    recorded: AtomicU64,
    dropped: AtomicU64,
    ring: Mutex<Ring>,
}

impl TraceSink {
    /// A sink retaining the most recent `capacity` traces;
    /// `capacity == 0` disables tracing entirely (inert traces, no
    /// allocation per request).
    pub fn with_capacity(capacity: usize) -> Self {
        TraceSink {
            capacity,
            ring: Mutex::new(Ring {
                buf: (0..capacity).map(|_| None).collect(),
                head: 0,
            }),
            ..TraceSink::default()
        }
    }

    /// `false` when built with capacity 0.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Ring capacity (0 ⇒ disabled).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Start a trace whose clock begins now. Nothing is stamped — the
    /// in-process submit path stamps `Admitted` as its first phase.
    pub fn begin(&self, kind: TraceKind) -> RequestTrace {
        self.begin_at(kind, Instant::now(), false)
    }

    /// Start a trace whose clock begins at `accepted` (the net server
    /// captures this when the frame's first byte arrives). `Accepted`
    /// is stamped at offset 0; the caller stamps `Decoded`.
    pub fn begin_from(&self, kind: TraceKind, accepted: Instant) -> RequestTrace {
        self.begin_at(kind, accepted, true)
    }

    fn begin_at(&self, kind: TraceKind, start: Instant, accepted: bool) -> RequestTrace {
        if !self.enabled() {
            return RequestTrace::inert();
        }
        let mut phases = [None; N_PHASES];
        if accepted {
            phases[TracePhase::Accepted.index()] = Some(0);
        }
        RequestTrace(Some(Box::new(TraceData {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            kind,
            start,
            phases,
            flush: None,
        })))
    }

    /// Next flush-group id, shared by the encrypted and plain batchers
    /// so every flush in the process is uniquely identified.
    pub fn next_flush_id(&self) -> u64 {
        self.next_flush.fetch_add(1, Ordering::Relaxed)
    }

    /// Push a completed trace into the ring. Inert traces are
    /// discarded; nothing further is stamped.
    pub fn record(&self, trace: RequestTrace) {
        let Some(d) = trace.0 else { return };
        let rec = TraceRecord {
            id: d.id,
            kind: d.kind,
            flush: d.flush,
            phases: d.phases,
        };
        self.recorded.fetch_add(1, Ordering::Relaxed);
        let mut ring = lock_unpoisoned(&self.ring);
        let idx = (ring.head % self.capacity as u64) as usize;
        if ring.buf[idx].is_some() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.buf[idx] = Some(rec);
        ring.head += 1;
    }

    /// Completed traces recorded since start (including overwritten).
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Traces overwritten by ring wrap-around (lost to capacity).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// The retained traces, oldest → newest. At most
    /// [`capacity`](TraceSink::capacity) records.
    pub fn snapshot(&self) -> Vec<TraceRecord> {
        if !self.enabled() {
            return Vec::new();
        }
        let ring = lock_unpoisoned(&self.ring);
        let cap = self.capacity as u64;
        let len = ring.head.min(cap);
        let start = ring.head - len;
        (0..len)
            .map(|i| {
                ring.buf[((start + i) % cap) as usize]
                    .clone()
                    .expect("ring slot below head is populated")
            })
            .collect()
    }
}

#[cfg(test)]
impl TraceSink {
    /// Poison the ring mutex the way `metrics.rs`'s test does: die on
    /// a spawned thread while holding it.
    fn lock_and_panic(&self) {
        let _g = self.ring.lock().unwrap();
        panic!("die holding the trace ring lock");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn finished(sink: &TraceSink, kind: TraceKind) -> RequestTrace {
        let mut t = sink.begin(kind);
        t.stamp(TracePhase::Admitted);
        t.stamp(TracePhase::Executing);
        t.stamp(TracePhase::Responded);
        t
    }

    #[test]
    fn disabled_sink_is_inert() {
        let sink = TraceSink::with_capacity(0);
        assert!(!sink.enabled());
        let mut t = sink.begin(TraceKind::Encrypted);
        assert!(!t.is_active());
        assert_eq!(t.id(), None);
        t.stamp(TracePhase::Admitted);
        t.stamp_batched(7, 3);
        sink.record(t);
        assert_eq!(sink.recorded(), 0);
        assert!(sink.snapshot().is_empty());
    }

    #[test]
    fn phases_are_stamped_once_and_ordered() {
        let sink = TraceSink::with_capacity(4);
        let mut t = sink.begin(TraceKind::Encrypted);
        t.stamp(TracePhase::Admitted);
        std::thread::sleep(Duration::from_millis(2));
        t.stamp_batched(11, 2);
        t.stamp(TracePhase::Executing);
        t.stamp(TracePhase::Responded);
        // Re-stamps keep the first timestamp and the first flush id.
        t.stamp(TracePhase::Executing);
        t.stamp_batched(99, 9);
        sink.record(t);

        let recs = sink.snapshot();
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert_eq!(r.kind, TraceKind::Encrypted);
        assert_eq!(r.flush, Some((11, 2)));
        assert_eq!(r.phase(TracePhase::Accepted), None);
        let admitted = r.phase(TracePhase::Admitted).expect("admitted");
        let batched = r.phase(TracePhase::Batched).expect("batched");
        let executing = r.phase(TracePhase::Executing).expect("executing");
        let responded = r.phase(TracePhase::Responded).expect("responded");
        assert!(admitted <= batched && batched <= executing && executing <= responded);
        assert!(batched >= Duration::from_millis(2));
        assert_eq!(
            r.queue_time().unwrap() + r.service_time().unwrap(),
            responded - admitted
        );
    }

    #[test]
    fn begin_from_stamps_accept_at_zero() {
        let sink = TraceSink::with_capacity(4);
        let accepted = Instant::now();
        std::thread::sleep(Duration::from_millis(1));
        let mut t = sink.begin_from(TraceKind::Plain, accepted);
        t.stamp(TracePhase::Decoded);
        sink.record(t);
        let r = &sink.snapshot()[0];
        assert_eq!(r.phase(TracePhase::Accepted), Some(Duration::ZERO));
        assert!(r.phase(TracePhase::Decoded).unwrap() >= Duration::from_millis(1));
    }

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let sink = TraceSink::with_capacity(3);
        for _ in 0..8 {
            sink.record(finished(&sink, TraceKind::Plain));
        }
        assert_eq!(sink.recorded(), 8);
        assert_eq!(sink.dropped(), 5);
        let recs = sink.snapshot();
        let ids: Vec<u64> = recs.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![5, 6, 7]);
    }

    /// The ISSUE's concurrency case: N writer threads record while a
    /// reader drains snapshots. The write cursor must not lose an
    /// update (recorded == N·K exactly) and every snapshot must
    /// respect the capacity bound with strictly increasing ids.
    #[test]
    fn concurrent_writers_and_reader_lose_nothing() {
        const WRITERS: usize = 8;
        const PER_WRITER: usize = 200;
        const CAPACITY: usize = 64;
        let sink = Arc::new(TraceSink::with_capacity(CAPACITY));

        let reader = {
            let sink = Arc::clone(&sink);
            std::thread::spawn(move || {
                let mut seen_max = 0u64;
                while sink.recorded() < (WRITERS * PER_WRITER) as u64 {
                    let snap = sink.snapshot();
                    assert!(snap.len() <= CAPACITY);
                    for w in snap.windows(2) {
                        assert!(w[0].id < w[1].id, "snapshot ids out of order");
                    }
                    if let Some(last) = snap.last() {
                        assert!(last.id >= seen_max, "newest id went backwards");
                        seen_max = last.id;
                    }
                    std::thread::yield_now();
                }
            })
        };

        let writers: Vec<_> = (0..WRITERS)
            .map(|_| {
                let sink = Arc::clone(&sink);
                std::thread::spawn(move || {
                    for _ in 0..PER_WRITER {
                        sink.record(finished(&sink, TraceKind::Encrypted));
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        reader.join().unwrap();

        assert_eq!(sink.recorded(), (WRITERS * PER_WRITER) as u64);
        assert_eq!(
            sink.dropped(),
            (WRITERS * PER_WRITER - CAPACITY) as u64,
            "every record beyond capacity overwrote exactly one slot"
        );
        let snap = sink.snapshot();
        assert_eq!(snap.len(), CAPACITY);
    }

    /// Mirrors `metrics.rs`'s poisoned-histogram test: a thread dies
    /// holding the ring lock; record and snapshot keep working.
    #[test]
    fn sink_survives_a_poisoned_ring_lock() {
        let sink = Arc::new(TraceSink::with_capacity(4));
        let s2 = Arc::clone(&sink);
        let _ = std::thread::spawn(move || s2.lock_and_panic()).join();
        assert!(sink.ring.is_poisoned());
        sink.record(finished(&sink, TraceKind::Encrypted));
        assert_eq!(sink.recorded(), 1);
        assert_eq!(sink.snapshot().len(), 1);
    }
}
