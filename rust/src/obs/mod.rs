//! Observability plane: request-scoped tracing and measured HE op
//! profiles.
//!
//! Two independent instruments, both strictly opt-in and zero-cost
//! when off:
//!
//! - **Span timelines** ([`trace`]): every serving-tier request
//!   carries a [`RequestTrace`] stamping µs offsets for the phases
//!   accepted → decoded → admitted → batched → executing → responded;
//!   completed traces land in the coordinator's [`TraceSink`] ring
//!   buffer (sized by `CoordinatorConfig::trace_capacity`, 0 = off)
//!   and are scrapeable in-process (`Metrics::trace`) or over the
//!   wire (`Request::TraceDump`). Flush ids tie together the requests
//!   that shared one batch flush.
//! - **Op profiles** ([`profile`]): [`TimingBackend`] decorates any
//!   `ScheduleBackend` and records wall time per schedule-op kind per
//!   pipeline segment into an [`OpProfile`] — the measured counterpart
//!   of the dry-run `CountingBackend`'s Table-1 predictions, with
//!   matching op multiplicities by construction. Entry point:
//!   `HrfServer::execute_profiled`.
//!
//! The wire-scrapable metrics themselves (counters, latency
//! histograms, `Request::MetricsSnapshot`) live in
//! `coordinator::metrics`; this module provides the trace and profile
//! machinery they surface.

pub mod profile;
pub mod trace;

pub use profile::{OpKind, OpProfile, ProfileCell, ProfileRow, TimingBackend};
pub use trace::{RequestTrace, TraceKind, TracePhase, TraceRecord, TraceSink, N_PHASES};
