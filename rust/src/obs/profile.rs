//! Measured per-op cost tables for the HE execution engine.
//!
//! [`TimingBackend`] is a [`ScheduleBackend`] decorator: it wraps any
//! backend, forwards every schedule primitive, and records the
//! primitive's wall time into an [`OpProfile`] — keyed by
//! `(pipeline segment, op kind)`, with a log₂ histogram per cell. Op
//! *multiplicities* are taken from the inner backend's own
//! [`op_counts`](ScheduleBackend::op_counts) snapshots (diffed around
//! each call), so a profile's totals are exactly the counts the
//! engine's segment accounting reports — and therefore exactly what
//! the dry-run `CountingBackend` predicts. That makes the profile a
//! *measured* Table 1: same rows, real nanoseconds attached.
//!
//! Profiling is strictly opt-in (`HrfServer::execute_profiled`); the
//! unprofiled `execute` path never constructs a decorator, so the hot
//! path carries no timing code, locks or allocations.

use crate::ckks::evaluator::OpCounts;
use crate::coordinator::metrics::Histogram;
use crate::hrf::schedule::{PlainOperand, Segment};
use crate::hrf::server::LayerCounts;
use crate::runtime::engine::ScheduleBackend;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::{Duration, Instant};

/// The schedule primitive a timing sample belongs to — one variant
/// per [`ScheduleBackend`] method.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OpKind {
    LoadInput,
    Rotate,
    Hoist,
    RotateHoisted,
    AddAssign,
    SubPlain,
    AddPlain,
    MulPlainCached,
    MulPlainRescale,
    AddConst,
    Rescale,
    PolyActivation,
    RotateSumGrouped,
    ReadScore,
}

impl OpKind {
    /// Stable snake_case name (tables, JSON).
    pub fn name(self) -> &'static str {
        match self {
            OpKind::LoadInput => "load_input",
            OpKind::Rotate => "rotate",
            OpKind::Hoist => "hoist",
            OpKind::RotateHoisted => "rotate_hoisted",
            OpKind::AddAssign => "add_assign",
            OpKind::SubPlain => "sub_plain",
            OpKind::AddPlain => "add_plain",
            OpKind::MulPlainCached => "mul_plain_cached",
            OpKind::MulPlainRescale => "mul_plain_rescale",
            OpKind::AddConst => "add_const",
            OpKind::Rescale => "rescale",
            OpKind::PolyActivation => "poly_activation",
            OpKind::RotateSumGrouped => "rotate_sum_grouped",
            OpKind::ReadScore => "read_score",
        }
    }
}

/// Accumulated timings for one `(segment, op kind)` cell.
#[derive(Debug, Default)]
pub struct ProfileCell {
    /// Schedule-primitive invocations (one per engine dispatch).
    pub calls: u64,
    /// Evaluator-level op counts those calls performed, diffed from
    /// the inner backend's counters (a `rotate_sum_grouped` call
    /// books several rotates and adds here).
    pub counts: OpCounts,
    /// Per-call wall time, log₂-bucketed in **nanoseconds**.
    pub nanos: Histogram,
}

/// One row of the rendered cost table.
#[derive(Clone, Debug)]
pub struct ProfileRow {
    pub segment: Segment,
    pub kind: OpKind,
    pub calls: u64,
    pub counts: OpCounts,
    pub total: Duration,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

/// Measured cost tables: wall time per schedule-op kind per pipeline
/// segment. Fill one via `HrfServer::execute_profiled` (or by wrapping
/// any backend in a [`TimingBackend`] yourself), then read it back as
/// [`rows`](OpProfile::rows), aggregate [`op_counts`](OpProfile::op_counts) /
/// [`layer_counts`](OpProfile::layer_counts), or a rendered
/// [`table`](OpProfile::table). Profiles accumulate across runs —
/// reuse one across many requests to tighten the histograms.
#[derive(Debug, Default)]
pub struct OpProfile {
    cells: BTreeMap<(Segment, OpKind), ProfileCell>,
}

impl OpProfile {
    /// Record one timed primitive invocation.
    pub fn record(&mut self, seg: Segment, kind: OpKind, elapsed: Duration, counts: OpCounts) {
        let cell = self.cells.entry((seg, kind)).or_default();
        cell.calls += 1;
        cell.counts += counts;
        cell.nanos.record_value(elapsed.as_nanos() as u64);
    }

    /// `true` until the first sample lands.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// The raw cells, ordered by `(segment, op kind)`.
    pub fn cells(&self) -> impl Iterator<Item = (&(Segment, OpKind), &ProfileCell)> {
        self.cells.iter()
    }

    /// Evaluator op counts summed over every cell. For a profile
    /// filled by one `execute_profiled` run this equals the engine's
    /// `LayerCounts::total()` — and the `CountingBackend` prediction.
    pub fn op_counts(&self) -> OpCounts {
        let mut total = OpCounts::default();
        for cell in self.cells.values() {
            total += cell.counts;
        }
        total
    }

    /// Evaluator op counts bucketed by pipeline segment — the measured
    /// counterpart of the engine's per-segment accounting.
    pub fn layer_counts(&self) -> LayerCounts {
        let mut counts = LayerCounts::default();
        for ((seg, _), cell) in &self.cells {
            *counts.bucket_mut(*seg) += cell.counts;
        }
        counts
    }

    /// Total wall time across every recorded primitive.
    pub fn total_time(&self) -> Duration {
        self.cells
            .values()
            .map(|c| Duration::from_nanos(c.nanos.sum_value() as u64))
            .sum()
    }

    /// Cost-table rows, most expensive (by total time) first.
    pub fn rows(&self) -> Vec<ProfileRow> {
        let mut rows: Vec<ProfileRow> = self
            .cells
            .iter()
            .map(|(&(segment, kind), cell)| ProfileRow {
                segment,
                kind,
                calls: cell.calls,
                counts: cell.counts,
                total: Duration::from_nanos(cell.nanos.sum_value() as u64),
                mean: Duration::from_nanos(cell.nanos.mean_value()),
                p50: Duration::from_nanos(cell.nanos.quantile_value(0.5)),
                p99: Duration::from_nanos(cell.nanos.quantile_value(0.99)),
            })
            .collect();
        rows.sort_by(|a, b| b.total.cmp(&a.total));
        rows
    }

    /// Render the cost table as aligned text (one line per
    /// segment×op cell, most expensive first).
    pub fn table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<9} {:<18} {:>7} {:>12} {:>10} {:>10} {:>10}",
            "segment", "op", "calls", "total_us", "mean_us", "p50_us", "p99_us"
        );
        for r in self.rows() {
            let _ = writeln!(
                out,
                "{:<9} {:<18} {:>7} {:>12.1} {:>10.1} {:>10.1} {:>10.1}",
                format!("{:?}", r.segment),
                r.kind.name(),
                r.calls,
                r.total.as_secs_f64() * 1e6,
                r.mean.as_secs_f64() * 1e6,
                r.p50.as_secs_f64() * 1e6,
                r.p99.as_secs_f64() * 1e6,
            );
        }
        out
    }
}

/// A [`ScheduleBackend`] decorator that times every primitive of the
/// wrapped backend into an [`OpProfile`]. Segment attribution comes
/// from the engine's [`on_segment`](ScheduleBackend::on_segment)
/// notifications; op multiplicities come from diffing the inner
/// backend's [`op_counts`](ScheduleBackend::op_counts) around each
/// call, so `op_counts()` (which delegates to the inner backend) and
/// the profile stay consistent by construction.
pub struct TimingBackend<'p, B: ScheduleBackend> {
    inner: B,
    profile: &'p mut OpProfile,
    seg: Segment,
}

impl<'p, B: ScheduleBackend> TimingBackend<'p, B> {
    /// Wrap `inner`, recording into `profile`. Attribution starts in
    /// the schedule's first segment ([`Segment::Pack`]) and follows
    /// the engine's segment notifications from there.
    pub fn new(inner: B, profile: &'p mut OpProfile) -> Self {
        TimingBackend {
            inner,
            profile,
            seg: Segment::Pack,
        }
    }

    /// Unwrap the decorated backend.
    pub fn into_inner(self) -> B {
        self.inner
    }

    fn timed<R>(&mut self, kind: OpKind, f: impl FnOnce(&mut B) -> R) -> R {
        let before = self.inner.op_counts();
        let t0 = Instant::now();
        let out = f(&mut self.inner);
        let elapsed = t0.elapsed();
        let counts = self.inner.op_counts().diff(&before);
        self.profile.record(self.seg, kind, elapsed, counts);
        out
    }
}

impl<B: ScheduleBackend> ScheduleBackend for TimingBackend<'_, B> {
    type Value = B::Value;
    type Hoisted = B::Hoisted;
    type Score = B::Score;

    fn load_input(&mut self, input: usize) -> Self::Value {
        self.timed(OpKind::LoadInput, |b| b.load_input(input))
    }

    fn rotate(&mut self, src: &Self::Value, step: usize) -> Self::Value {
        self.timed(OpKind::Rotate, |b| b.rotate(src, step))
    }

    fn hoist(&mut self, src: &Self::Value) -> Self::Hoisted {
        self.timed(OpKind::Hoist, |b| b.hoist(src))
    }

    fn rotate_hoisted(
        &mut self,
        src: &Self::Value,
        hoisted: &Self::Hoisted,
        step: usize,
    ) -> Self::Value {
        self.timed(OpKind::RotateHoisted, |b| b.rotate_hoisted(src, hoisted, step))
    }

    fn add_assign(&mut self, dst: &mut Self::Value, src: &mut Self::Value) {
        self.timed(OpKind::AddAssign, |b| b.add_assign(dst, src));
    }

    fn sub_plain(&mut self, reg: &mut Self::Value, operand: PlainOperand) {
        self.timed(OpKind::SubPlain, |b| b.sub_plain(reg, operand));
    }

    fn add_plain(&mut self, reg: &mut Self::Value, operand: PlainOperand) {
        self.timed(OpKind::AddPlain, |b| b.add_plain(reg, operand));
    }

    fn mul_plain_cached(&mut self, src: &Self::Value, operand: PlainOperand) -> Self::Value {
        self.timed(OpKind::MulPlainCached, |b| b.mul_plain_cached(src, operand))
    }

    fn mul_plain_rescale(&mut self, src: &Self::Value, operand: PlainOperand) -> Self::Value {
        // Forward to the inner backend's (possibly fused) kernel
        // rather than the trait default, which would decompose into an
        // unfused pair and skew both the timing and the counts.
        self.timed(OpKind::MulPlainRescale, |b| b.mul_plain_rescale(src, operand))
    }

    fn add_const(&mut self, reg: &mut Self::Value, value: f64) {
        self.timed(OpKind::AddConst, |b| b.add_const(reg, value));
    }

    fn rescale(&mut self, reg: &mut Self::Value) {
        self.timed(OpKind::Rescale, |b| b.rescale(reg));
    }

    fn poly_activation(&mut self, src: &Self::Value) -> Self::Value {
        self.timed(OpKind::PolyActivation, |b| b.poly_activation(src))
    }

    fn rotate_sum_grouped(&mut self, src: &Self::Value, span: usize) -> Self::Value {
        self.timed(OpKind::RotateSumGrouped, |b| b.rotate_sum_grouped(src, span))
    }

    fn read_score(&mut self, value: &Self::Value, slot: usize) -> Self::Score {
        self.timed(OpKind::ReadScore, |b| b.read_score(value, slot))
    }

    fn op_counts(&self) -> OpCounts {
        self.inner.op_counts()
    }

    fn on_segment(&mut self, seg: Segment) {
        self.seg = seg;
        self.inner.on_segment(seg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::CountingBackend;

    #[test]
    fn timing_decorator_matches_inner_counts() {
        // Drive a CountingBackend by hand through the decorator: the
        // profile's aggregate counts must equal the inner backend's
        // own counters, and calls must land in the stamped segment.
        let mut profile = OpProfile::default();
        let act = OpCounts {
            mul: 2,
            add_plain: 1,
            rescale: 2,
            relin: 2,
            ..OpCounts::default()
        };
        let mut b = TimingBackend::new(CountingBackend::new(act), &mut profile);

        b.on_segment(Segment::Layer1);
        let v = b.load_input(0);
        let r = b.rotate(&v, 4);
        let h = b.hoist(&r);
        let _ = b.rotate_hoisted(&r, &h, 2);
        b.on_segment(Segment::Act1);
        let _ = b.poly_activation(&v);

        let inner_counts = b.op_counts();
        let measured = b.into_inner().op_counts();
        assert_eq!(inner_counts, measured);
        assert_eq!(profile.op_counts(), measured);

        let lc = profile.layer_counts();
        assert_eq!(lc.layer1.rotate, measured.rotate);
        assert_eq!(lc.activations, act, "Act1 calls attributed to the activations bucket");
        assert_eq!(lc.total(), measured);

        let rows = profile.rows();
        assert!(!rows.is_empty());
        let calls: u64 = rows.iter().map(|r| r.calls).sum();
        assert_eq!(calls, 5);
        for r in &rows {
            assert!(r.p50 <= r.p99);
            assert!(r.total >= r.mean);
        }
        assert!(!profile.table().is_empty());
        assert!(profile.total_time() > Duration::ZERO);
    }
}
