//! A2 — ablation over CKKS parameter sets: ring degree vs latency of
//! the HRF building blocks, decode precision, and the packing budget
//! L(2K−1) ≤ N/2. Quantifies the cost of moving from the dev chain to
//! the 128-bit chain (same code path, bigger ring).

use cryptotree::bench_harness::{bench, fmt_dur, print_metric_table};
use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::rng::Xoshiro256pp;

fn main() {
    let mut rows = Vec::new();
    for params in [
        CkksParams::toy(),
        CkksParams::fast(),
        CkksParams::hrf_default(),
    ] {
        let ctx = CkksContext::new(params.clone());
        let enc = Encoder::new(&ctx);
        let mut kg = KeyGenerator::new(&ctx, 61);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let gk = kg.gen_galois_keys(&ctx, &[1]);
        let mut encryptor = Encryptor::new(pk, 62);
        let decryptor = Decryptor::new(kg.secret_key());
        let mut ev = Evaluator::new(ctx.clone());
        let mut rng = Xoshiro256pp::new(63);
        let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let ct = encryptor.encrypt_slots(&ctx, &enc, &z);

        let t_mul = bench("mul", 1, 5, || ev.mul(&ct, &ct, &rlk));
        let t_rot = bench("rot", 1, 5, || ev.rotate(&ct, 1, &gk));
        let pt = enc.encode(&ctx, &z, ct.level, ctx.params.scale);
        let t_pmul = bench("pmul", 1, 5, || ev.mul_plain(&ct, &pt));

        // Decode precision of a fresh encryption.
        let back = decryptor.decrypt_slots(&ctx, &enc, &ct);
        let max_err = back
            .iter()
            .zip(&z)
            .map(|(g, e)| (g - e).abs())
            .fold(0.0f64, f64::max);
        let max_l_k16 = ctx.params.slots() / 31;
        rows.push(vec![
            params.name.to_string(),
            params.depth().to_string(),
            format!("{:.0}", params.log_qp()),
            params.security_estimate().split(' ').next().unwrap().to_string(),
            fmt_dur(t_mul.median),
            fmt_dur(t_rot.median),
            fmt_dur(t_pmul.median),
            format!("{max_err:.2e}"),
            max_l_k16.to_string(),
        ]);
    }
    print_metric_table(
        "Ablation — CKKS parameter sets",
        &[
            "params", "depth", "logQP", "security", "ct*ct", "rotate", "ct*pt",
            "fresh err", "max L (K=16)",
        ],
        &rows,
    );
    println!("\nsecure128 (N=32768) follows the same curve at ~2x hrf_default cost;");
    println!("run with CRYPTOTREE_SECURE=1 to include it (slow on this box).");
    if std::env::var("CRYPTOTREE_SECURE").is_ok() {
        let params = CkksParams::secure128();
        let ctx = CkksContext::new(params.clone());
        let enc = Encoder::new(&ctx);
        let mut kg = KeyGenerator::new(&ctx, 64);
        let pk = kg.gen_public_key(&ctx);
        let rlk = kg.gen_relin_key(&ctx);
        let mut encryptor = Encryptor::new(pk, 65);
        let mut ev = Evaluator::new(ctx.clone());
        let mut rng = Xoshiro256pp::new(66);
        let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let ct = encryptor.encrypt_slots(&ctx, &enc, &z);
        let t_mul = bench("mul", 1, 3, || ev.mul(&ct, &ct, &rlk));
        println!("secure128 ct*ct median: {}", fmt_dur(t_mul.median));
    }
}
