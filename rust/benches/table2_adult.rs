//! E2/E3 — Table 2 reproduction (bench-sized): Accuracy / Precision /
//! Recall / F1 for Linear, RF, NRF and HRF on synthetic Adult Income,
//! plus the §4 NRF/HRF agreement statistic.
//!
//! This is the fast (bench) variant: 12k rows, 32 trees, 25 encrypted
//! samples. The full-scale driver is `examples/adult_income_e2e.rs`
//! (48 842 rows, 64 trees) — same code paths, bigger numbers.

use cryptotree::bench_harness::print_metric_table;
use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::data::adult;
use cryptotree::forest::linear::LogRegConfig;
use cryptotree::forest::metrics::{agreement, Metrics};
use cryptotree::forest::{LogisticRegression, RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::{finetune_last_layer, FinetuneConfig, NeuralForest};

fn main() {
    let ds = adult::generate(12_000, 1);
    let (train, valid) = ds.split(0.8, 2);

    let linear = LogisticRegression::fit(&train, &LogRegConfig::default(), 3);
    let m_linear = Metrics::from_predictions(
        &valid.x.iter().map(|x| linear.predict(x)).collect::<Vec<_>>(),
        &valid.y,
    );

    let rf = RandomForest::fit(
        &train,
        &RandomForestConfig {
            n_trees: 32,
            ..Default::default()
        },
        4,
    );
    let m_rf = Metrics::from_predictions(&rf.predict_batch(&valid.x), &valid.y);

    let a = 3.0;
    let mut nf = NeuralForest::from_forest(&rf, Activation::Tanh { a });
    finetune_last_layer(&mut nf, &train, &FinetuneConfig::default(), 5);
    let m_nrf = Metrics::from_predictions(&nf.predict_batch(&valid.x), &valid.y);

    // HRF: encrypted evaluation of the polynomial-activation twin.
    let nf_poly = nf.with_activation(Activation::Poly {
        coeffs: chebyshev_fit_tanh(a, 4),
    });
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model =
        HrfModel::from_neural_forest(&nf_poly, ds.n_features(), params.slots()).unwrap();
    let mut kg = KeyGenerator::new(&ctx, 6);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &model.plan.rotations_needed());
    let mut client = HrfClient::new(Encryptor::new(pk, 7), Decryptor::new(kg.secret_key()));
    let server = HrfServer::new(model);
    let mut ev = Evaluator::new(ctx.clone());

    let n_hrf = 25.min(valid.len());
    let mut hrf_pred = Vec::new();
    let mut nrf_pred = Vec::new();
    for i in 0..n_hrf {
        let x = &valid.x[i];
        let ct = client.encrypt_input(&ctx, &enc, &server.model, x);
        let outs = server
            .execute(&mut ev, &enc, &EncRequest::single(&ct), &rlk, &gk)
            .into_class_scores();
        let (_, pred) = client.decrypt_scores(&ctx, &enc, &outs);
        hrf_pred.push(pred);
        nrf_pred.push(nf.predict(x));
    }
    let m_hrf = Metrics::from_predictions(&hrf_pred, &valid.y[..n_hrf]);

    print_metric_table(
        "Table 2 — Adult Income (bench-sized reproduction)",
        &["Model", "Accuracy", "Precision", "Recall", "F1"],
        &[
            m_linear.table_row("Linear"),
            m_rf.table_row("RF"),
            m_nrf.table_row("NRF"),
            m_hrf.table_row(&format!("HRF (n={n_hrf})")),
        ],
    );
    println!(
        "\nNRF/HRF agreement: {:.1}% over {n_hrf} encrypted samples (paper §4: 97.5%)",
        100.0 * agreement(&hrf_pred, &nrf_pred)
    );
    println!("Paper Table 2: Linear .819/.432/.724/.541 | RF .834/.386/.876/.536 | NRF .845/.547/.762/.637 | HRF .842/.491/.796/.607");
    println!("Reproduction target is the *ordering* (NRF ≥ RF > Linear, HRF ≈ NRF), not absolute values (synthetic data).");

    // Shape assertions (soft reproduction criteria).
    assert!(m_rf.accuracy > m_linear.accuracy - 0.02, "RF should not trail Linear");
    assert!(m_nrf.accuracy >= m_rf.accuracy - 0.02, "fine-tuned NRF ≈/≥ RF");
}
