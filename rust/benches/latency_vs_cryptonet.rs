//! E4 — §5 comparison: HRF single-observation latency vs a
//! CryptoNet-style batched HE-MLP on the same CKKS substrate.
//!
//! Paper claim: CryptoNets amortize well (570 s / 8192-image batch on
//! 2016 hardware) but a single observation costs the *full* batch
//! latency, while HRF answers one encrypted query in ~3 s. Absolute
//! numbers differ on this testbed; the reproduction target is the
//! crossover shape:
//!
//!   HRF single-shot  ≪  HE-MLP single-shot  (= HE-MLP batch)
//!   HE-MLP amortized ≪  HRF single-shot     (batching wins throughput)

use cryptotree::bench_harness::{bench, fmt_dur, print_metric_table};
use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::cryptonet::{encrypt_batch_per_feature, eval_mlp, MlpWeights};
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;

fn main() {
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let slots = params.slots();

    // ---------------- HRF (single observation) ---------------------
    let ds = adult::generate(2_000, 31);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 64,
            ..Default::default()
        },
        32,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    );
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), slots).unwrap();
    let mut kg = KeyGenerator::new(&ctx, 33);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &model.plan.rotations_needed());
    let mut client = HrfClient::new(Encryptor::new(pk, 34), Decryptor::new(kg.secret_key()));
    let server = HrfServer::new(model);
    let mut ev = Evaluator::new(ctx.clone());
    let ct = client.encrypt_input(&ctx, &enc, &server.model, &ds.x[0]);
    let t_hrf = bench("hrf single", 1, 5, || {
        server.execute(&mut ev, &enc, &EncRequest::single(&ct), &rlk, &gk)
    });

    // ---------------- CryptoNet-style HE-MLP -----------------------
    // d=14 features, hidden 32, square activations; the batch fills
    // the slots (CryptoNet layout: one ciphertext per feature, one
    // sample per slot).
    let d = 14;
    let hidden = 32;
    let w = MlpWeights::random(d, hidden, 2, 35);
    let mut kg2 = KeyGenerator::new(&ctx, 36);
    let pk2 = kg2.gen_public_key(&ctx);
    let rlk2 = kg2.gen_relin_key(&ctx);
    let mut enc2 = Encryptor::new(pk2, 37);
    let batch: Vec<Vec<f64>> = (0..slots.min(2_000))
        .map(|i| ds.x[i % ds.len()].clone())
        .collect();
    let cts = encrypt_batch_per_feature(&ctx, &enc, &mut enc2, &batch);
    let mut ev2 = Evaluator::new(ctx.clone());
    let t_mlp = bench("he-mlp batch", 0, 3, || eval_mlp(&mut ev2, &enc, &cts, &w, &rlk2));

    // ---------------- report ---------------------------------------
    let hrf_single = t_hrf.median;
    let mlp_batch = t_mlp.median;
    let mlp_amortized = mlp_batch / slots as u32;
    print_metric_table(
        "§5 — single-observation latency vs batch amortization",
        &["system", "single-shot", "batch (=B samples)", "amortized/sample"],
        &[
            vec![
                format!("HRF (L=64, K=16, N={})", params.n),
                fmt_dur(hrf_single),
                "n/a (no batching needed)".into(),
                fmt_dur(hrf_single),
            ],
            vec![
                format!("HE-MLP CryptoNet-style (d={d}, h={hidden}, B={slots})"),
                fmt_dur(mlp_batch),
                fmt_dur(mlp_batch),
                fmt_dur(mlp_amortized),
            ],
        ],
    );
    println!(
        "\nHRF single-shot is {:.1}x faster than the HE-MLP's single-shot latency;",
        mlp_batch.as_secs_f64() / hrf_single.as_secs_f64()
    );
    println!(
        "the HE-MLP amortized cost is {:.1}x below HRF — the paper's trade-off, reproduced.",
        hrf_single.as_secs_f64() / mlp_amortized.as_secs_f64()
    );
    println!("(paper: HRF ~3s single vs CryptoNet 570s/8192 batch = 70ms amortized)");
    assert!(mlp_batch > hrf_single, "crossover shape violated");
}
