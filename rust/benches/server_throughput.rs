//! E5 — §5 "multi-threaded server": encrypted-request throughput as a
//! function of worker count, plus the cross-instance SIMD batching
//! added on top of the paper (pack B observations into the free sample
//! groups of one ciphertext and evaluate once).
//!
//! On a multi-core deployment the encrypted path scales near-linearly
//! in workers (each worker owns an independent CKKS evaluator and the
//! work is embarrassingly parallel across requests). This testbed has
//! a single core, so the expected *measured* shape there is flat — the
//! bench prints cores so the reader can interpret the curve. SIMD
//! batching, by contrast, amortizes a *single* evaluation across B
//! samples, so it pays even on one core.

use cryptotree::bench_harness::{bench, print_metric_table, write_json, BenchRecord};
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, Evaluator, KeyGenerator};
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager, SubmitError};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::{reshuffle_and_pack_group, HrfClient};
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;
use cryptotree::runtime::{SlotModel, SlotModelParams, SlotShape};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    // The paper's default adult configuration: L=64 trees, K=16 leaves
    // -> 1984 of 4096 slots used per sample group, 2 groups/ciphertext
    // on the fast N=8192 parameter set.
    let ds = adult::generate(1_500, 41);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 64,
            ..Default::default()
        },
        42,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    );
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model =
        HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
    let plan = model.plan;
    let b_max = plan.groups;
    println!(
        "plan: K={} L={} C={} | {} of {} slots/group, span {}, {} sample groups/ct",
        plan.k, plan.l, plan.c, plan.used_slots, plan.slots, plan.reduce_span, b_max
    );
    let server = Arc::new(HrfServer::new(model));
    let mut kg = KeyGenerator::new(&ctx, 43);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    // Keys cover batched groups up to the plan's capacity, so both the
    // single-sample and the packed protocol run under one session.
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(b_max));
    let mut client = HrfClient::new(Encryptor::new(pk, 44), Decryptor::new(kg.secret_key()));

    // ---- SIMD batching: samples/sec for B in {1, max} --------------
    // Records land in BENCH_server_throughput.json (ROADMAP
    // §Benchmarking) so the serving-path trajectory is tracked per PR.
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rows = Vec::new();
    for b in [1usize, b_max] {
        let xs: Vec<Vec<f64>> = (0..b).map(|i| ds.x[i].clone()).collect();
        let ct = client.encrypt_batch(&ctx, &enc, &server.model, &xs);
        let mut ev = Evaluator::new(ctx.clone());
        let t = bench(&format!("hrf eval B={b}"), 1, 3, || {
            server.execute(&mut ev, &enc, &EncRequest::single(&ct), &rlk, &gk)
        });
        records.push(BenchRecord::from_timing(&t, ctx.workers(), params.name));
        rows.push(vec![
            format!("{b}"),
            format!("{:?}", t.median),
            format!("{:.3}", t.throughput(b as f64)),
        ]);
    }
    print_metric_table(
        "SIMD sample-group batching — one HE evaluation, B packed samples",
        &["B", "eval (median)", "samples/sec"],
        &rows,
    );

    // ---- Plaintext slot-model oracle, same B sweep -----------------
    let shape = SlotShape {
        s: plan.slots,
        k: plan.k,
        c: plan.c,
        m: server.model.act_coeffs.len(),
        b: 8,
    };
    let sm = SlotModel { shape };
    let smp = SlotModelParams::from_hrf(&server.model, shape).unwrap();
    let mut rows = Vec::new();
    for b in [1usize, b_max] {
        let xs: Vec<Vec<f64>> = (0..b).map(|i| ds.x[i].clone()).collect();
        let packed: Vec<f32> = reshuffle_and_pack_group(&server.model, &xs)
            .iter()
            .map(|&v| v as f32)
            .collect();
        let t = bench(&format!("slot model B={b}"), 3, 20, || {
            sm.infer_packed(&packed, b, &smp).unwrap()
        });
        rows.push(vec![
            format!("{b}"),
            format!("{:?}", t.median),
            format!("{:.1}", t.throughput(b as f64)),
        ]);
    }
    print_metric_table(
        "plaintext slot-model oracle — packed groups (predicts HE amortization)",
        &["B", "infer (median)", "samples/sec"],
        &rows,
    );

    // ---- Coordinator: encrypted throughput vs workers --------------
    // enc_batch = groups: single-sample submissions from one session
    // are transparently packed server-side.
    let pool: Vec<_> = (0..4)
        .map(|i| client.encrypt_input(&ctx, &enc, &server.model, &ds.x[i]))
        .collect();
    let mut rows = Vec::new();
    for workers in [1usize, 2, 4] {
        let sessions = Arc::new(SessionManager::new());
        let sid = sessions.register(rlk.clone(), gk.clone());
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers,
                queue_capacity: 64,
                enc_batch: b_max,
                ..Default::default()
            },
            ctx.clone(),
            server.clone(),
            sessions,
            None,
        );
        let n_req = 6usize;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_req)
            .map(|i| loop {
                match coord.submit_encrypted(sid, pool[i % pool.len()].clone()) {
                    Ok(rx) => break rx,
                    Err(SubmitError::Busy) => std::thread::sleep(Duration::from_millis(2)),
                    Err(e) => panic!("{e:?}"),
                }
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().expect("eval");
        }
        let elapsed = t0.elapsed();
        let snap = coord.metrics.snapshot();
        // `threads` is the limb-parallel count (1 here); the
        // coordinator's request-level worker count lives in the op name.
        records.push(BenchRecord::from_ns(
            &format!("enc request (coordinator, workers={workers})"),
            elapsed.as_secs_f64() * 1e9 / n_req as f64,
            ctx.workers(),
            params.name,
        ));
        rows.push(vec![
            workers.to_string(),
            format!("{:.3}", n_req as f64 / elapsed.as_secs_f64()),
            format!("{:.2}", snap.mean_enc_batch_fill),
            format!("{:?}", snap.encrypted_mean),
            format!("{:?}", snap.encrypted_p95),
        ]);
        coord.shutdown();
    }
    print_metric_table(
        &format!(
            "§5 — encrypted throughput vs workers, enc_batch={} ({} host cores)",
            b_max,
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        ),
        &["workers", "enc req/s", "mean fill", "mean latency", "p95 latency"],
        &rows,
    );
    println!("\nSingle-core testbed: flat worker scaling expected here; SIMD group");
    println!("batching amortizes one evaluation across B samples regardless of cores.");

    // ---- Adaptive enc_batch: fill/latency Pareto -------------------
    // The coordinator's forming target scales with queue depth
    // (CoordinatorConfig::adaptive_enc_batch): a burst stacks the
    // queue and flushes full groups (high fill, amortized cost), a
    // paced trickle flushes near-singletons after the idle grace (low
    // latency, low fill). One knob, both ends of the Pareto front.
    let mut rows = Vec::new();
    for enc_batch in [1usize, b_max] {
        for &(load, pace) in &[("burst", Duration::ZERO), ("paced", Duration::from_millis(40))] {
            let sessions = Arc::new(SessionManager::new());
            let sid = sessions.register(rlk.clone(), gk.clone());
            let coord = Coordinator::start(
                CoordinatorConfig {
                    workers: 1,
                    queue_capacity: 64,
                    enc_batch,
                    adaptive_enc_batch: true,
                    ..Default::default()
                },
                ctx.clone(),
                server.clone(),
                sessions,
                None,
            );
            let n_req = 6usize;
            let rxs: Vec<_> = (0..n_req)
                .map(|i| {
                    if !pace.is_zero() && i > 0 {
                        std::thread::sleep(pace);
                    }
                    loop {
                        match coord.submit_encrypted(sid, pool[i % pool.len()].clone()) {
                            Ok(rx) => break rx,
                            Err(SubmitError::Busy) => {
                                std::thread::sleep(Duration::from_millis(2))
                            }
                            Err(e) => panic!("{e:?}"),
                        }
                    }
                })
                .collect();
            for rx in rxs {
                rx.recv().unwrap().expect("eval");
            }
            let snap = coord.metrics.snapshot();
            rows.push(vec![
                format!("{enc_batch}"),
                load.to_string(),
                format!("{:.2}", snap.mean_enc_batch_fill),
                format!("{:.2}", snap.enc_batch_fill_ratio),
                format!("{:?}", snap.encrypted_mean),
                format!("{:?}", snap.encrypted_p95),
            ]);
            coord.shutdown();
        }
    }
    print_metric_table(
        "adaptive enc_batch — fill/latency Pareto (queue-depth-scaled target)",
        &["enc_batch", "load", "mean fill", "fill ratio", "mean latency", "p95 latency"],
        &rows,
    );
    println!("\nBurst rows show the depth-scaled target filling groups; paced rows show");
    println!("the idle grace trading fill for latency. Pick enc_batch for the SLO, let");
    println!("the adaptive target harvest batching whenever load actually builds.");

    // ---- DAG executor: op-workers × limb-workers grid --------------
    // The two parallelism axes compose: op_workers runs independent
    // schedule ops concurrently (one evaluator + scratch each),
    // ckks_workers splits each op's RNS limbs. Outputs are
    // bit-identical at every grid point; only the wall clock moves.
    let st = server.dag_stats(b_max, true);
    println!(
        "\nschedule DAG B={b_max}: {} ops, {} waves, width {} (op-parallel ceiling)",
        st.ops, st.waves, st.width
    );
    let xs: Vec<Vec<f64>> = (0..b_max).map(|i| ds.x[i].clone()).collect();
    let ct = client.encrypt_batch(&ctx, &enc, &server.model, &xs);
    let mut rows = Vec::new();
    for ow in [1usize, 2, 4] {
        for cw in [1usize, 2, 4] {
            server.set_op_workers(ow);
            ctx.set_workers(cw);
            let mut ev = Evaluator::new(ctx.clone());
            let t = bench(&format!("hrf eval B={b_max} [ow={ow} cw={cw}]"), 1, 3, || {
                server.execute(&mut ev, &enc, &EncRequest::single(&ct), &rlk, &gk)
            });
            // `threads` carries the limb-parallel count (matching the
            // primitive benches); op_workers lives in the op name.
            records.push(BenchRecord::from_ns(
                &format!("hrf eval B={b_max} dag [op_workers={ow}]"),
                t.median.as_secs_f64() * 1e9,
                cw,
                params.name,
            ));
            rows.push(vec![
                ow.to_string(),
                cw.to_string(),
                format!("{:?}", t.median),
                format!("{:.3}", t.throughput(b_max as f64)),
            ]);
        }
    }
    server.set_op_workers(1);
    ctx.set_workers(1);
    print_metric_table(
        "DAG executor — op_workers × ckks_workers (bit-identical outputs)",
        &["op_workers", "ckks_workers", "eval (median)", "samples/sec"],
        &rows,
    );
    println!("\nop_workers pays on wide waves (independent per-class chains); ckks_workers");
    println!("pays inside big single ops. On a single core both curves read flat.");

    write_json("BENCH_server_throughput.json", &records).expect("write bench json");
}
