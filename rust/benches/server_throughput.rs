//! E5 — §5 "multi-threaded server": encrypted-request throughput as a
//! function of worker count, plus plaintext fast-path throughput.
//!
//! On a multi-core deployment the encrypted path scales near-linearly
//! in workers (each worker owns an independent CKKS evaluator and the
//! work is embarrassingly parallel across requests). This testbed has
//! a single core, so the expected *measured* shape here is flat — the
//! bench prints cores so the reader can interpret the curve.

use cryptotree::bench_harness::print_metric_table;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager, SubmitError};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let ds = adult::generate(1_500, 41);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 16,
            ..Default::default()
        },
        42,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    );
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model =
        HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
    let plan = model.plan;
    let server = Arc::new(HrfServer::new(model));
    let mut kg = KeyGenerator::new(&ctx, 43);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
    let mut client = HrfClient::new(Encryptor::new(pk, 44), Decryptor::new(kg.secret_key()));
    let pool: Vec<_> = (0..4)
        .map(|i| client.encrypt_input(&ctx, &enc, &server.model, &ds.x[i]))
        .collect();

    let mut rows = Vec::new();
    for workers in [1usize, 2, 4] {
        let sessions = Arc::new(SessionManager::new());
        let sid = sessions.register(rlk.clone(), gk.clone());
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers,
                queue_capacity: 64,
                ..Default::default()
            },
            ctx.clone(),
            server.clone(),
            sessions,
            None,
        );
        let n_req = 6usize;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_req)
            .map(|i| loop {
                match coord.submit_encrypted(sid, pool[i % pool.len()].clone()) {
                    Ok(rx) => break rx,
                    Err(SubmitError::Busy) => std::thread::sleep(Duration::from_millis(2)),
                    Err(e) => panic!("{e:?}"),
                }
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().expect("eval");
        }
        let elapsed = t0.elapsed();
        let snap = coord.metrics.snapshot();
        rows.push(vec![
            workers.to_string(),
            format!("{:.3}", n_req as f64 / elapsed.as_secs_f64()),
            format!("{:?}", snap.encrypted_mean),
            format!("{:?}", snap.encrypted_p95),
        ]);
        coord.shutdown();
    }
    print_metric_table(
        &format!(
            "§5 — encrypted throughput vs workers ({} host cores)",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        ),
        &["workers", "enc req/s", "mean latency", "p95 latency"],
        &rows,
    );
    println!("\nSingle-core testbed: flat scaling expected here; the per-request");
    println!("work is independent, so multi-core deployments scale with workers.");
}
