//! E1 — Table 1 reproduction: homomorphic op counts per HRF linear
//! layer, **predicted by the compiled schedule's dry-run interpreter**
//! and verified against the evaluator's measured counters, sweeping K
//! and L; paper closed forms printed alongside for reference.
//!
//! Paper formulas:  L1 (1, 0, 0) · L2 (K, K, K) · L3 (C⌈log₂L(2K−1)⌉, C, C⌈log₂L(2K−1)⌉)
//! Note: our Algorithm 1 skips the identity rotation (j = 0), so the
//! schedule's L2 rotation count is K−1 — one fewer than the paper's K.
//! L3 additions include the C bias additions (paper counts reductions
//! only).
//!
//! A second section measures the **extraction fold**: for B packed
//! samples the folded schedule executes exactly C·(B−1) fewer
//! rotations than the legacy eval+extract path (`eval_batch_reference`).
//!
//! A third section measures the **FuseMulRescale schedule pass**: the
//! standard pipeline fuses layer 3's C adjacent MulPlainCached+Rescale
//! pairs into single fused ops — the schedule shrinks by C ops and the
//! stand-alone `mul_plain` / `rescale` counters drop by C each (the
//! pairs re-book as `fused_mul_rescale`), while execution stays
//! bit-identical to the unoptimized schedule.

use cryptotree::bench_harness::print_metric_table;
use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::data::adult;
use cryptotree::forest::tree::TreeConfig;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{EncRequest, HrfModel, HrfSchedule, HrfServer, LayerCounts};
use cryptotree::runtime::PassPipeline;
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;

fn build_server(k: usize, l: usize, seed: u64) -> (HrfServer, CkksSetup) {
    let depth = k.trailing_zeros() as usize; // K = 2^depth
    let ds = adult::generate(1_200, 900 + seed);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: l,
            tree: TreeConfig {
                max_depth: depth,
                ..Default::default()
            },
            ..Default::default()
        },
        901,
    );
    // Pad every tree to exactly the sweep K (NeuralTree handles dead
    // leaves/comparisons), bypassing the forest's automatic K choice.
    let trees: Vec<_> = rf
        .trees
        .iter()
        .map(|t| cryptotree::nrf::NeuralTree::from_tree(t, k))
        .collect();
    let nf = NeuralForest {
        trees,
        alphas: rf.alphas.clone(),
        k,
        n_classes: rf.n_classes,
        activation: Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    };
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
    let plan = model.plan;
    let mut kg = KeyGenerator::new(&ctx, 902);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    // Superset keys: legacy eval+extract AND the folded schedule run
    // under one session.
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(plan.groups));
    let client = HrfClient::new(Encryptor::new(pk, 903), Decryptor::new(kg.secret_key()));
    let setup = CkksSetup {
        ctx,
        enc,
        client,
        rlk,
        gk,
        xs: ds.x,
    };
    (HrfServer::new(model), setup)
}

struct CkksSetup {
    ctx: cryptotree::ckks::rns::ContextRef,
    enc: Encoder,
    client: HrfClient,
    rlk: cryptotree::ckks::keys::RelinKey,
    gk: cryptotree::ckks::keys::GaloisKeys,
    xs: Vec<Vec<f64>>,
}

fn measure(k: usize, l: usize) -> (LayerCounts, LayerCounts) {
    let (server, mut s) = build_server(k, l, k as u64);
    let mut ev = Evaluator::new(s.ctx.clone());
    let ct = s.client.encrypt_input(&s.ctx, &s.enc, &server.model, &s.xs[0]);
    let counts = server
        .execute(&mut ev, &s.enc, &EncRequest::single(&ct), &s.rlk, &s.gk)
        .counts;
    (server.predicted_counts(1, true), counts)
}

fn main() {
    // ---- Table 1: schedule-predicted vs measured -------------------
    let mut rows = Vec::new();
    for (k, l) in [(8usize, 16usize), (8, 64), (16, 16), (16, 64), (32, 16)] {
        let plan = cryptotree::hrf::HrfPlan::new(k, l, 2, 14, 4096).unwrap();
        let formulas = plan.table1_formulas();
        let (predicted, measured) = measure(k, l);
        let pred_rows = predicted.table1_rows();
        let meas_rows = measured.table1_rows();
        for (i, layer) in ["L1", "L2", "L3"].iter().enumerate() {
            let (fa, fm, fr) = formulas[i];
            let (pa, pm, pr) = pred_rows[i];
            let (ma, mm, mr) = meas_rows[i];
            rows.push(vec![
                format!("K={k} L={l}"),
                layer.to_string(),
                format!("{fa} / {pa} / {ma}"),
                format!("{fm} / {pm} / {mm}"),
                format!("{fr} / {pr} / {mr}"),
            ]);
        }
        // The dry-run interpreter IS the source of truth now: measured
        // execution must match it op for op.
        assert_eq!(predicted, measured, "K={k} L={l}: prediction drift");
        // Invariants the paper's Table 1 asserts:
        assert_eq!(meas_rows[0], (1, 0, 0), "L1 shape");
        assert_eq!(meas_rows[1].1, k as u64, "L2 multiplications = K");
        assert_eq!(
            meas_rows[1].2,
            (k - 1) as u64,
            "L2 rotations = K-1 (identity skipped)"
        );
        assert_eq!(meas_rows[2].1, 2, "L3 multiplications = C");
    }
    print_metric_table(
        "Table 1 — op counts per linear layer: paper formula / schedule dry-run / measured",
        &["plan", "layer", "additions", "multiplications", "rotations"],
        &rows,
    );
    println!("\nL2 rotations: schedule emits K-1 (identity rotation skipped); paper counts K.");
    println!("L3 additions: measured includes the C bias additions.");
    println!("Key property (paper §3): costs depend on K and C only — compare L=16 vs L=64 rows.");

    // ---- Extraction fold: folded schedule vs legacy eval+extract ---
    // K=8, L=16 on 4096 slots -> span 256 -> 16 sample groups.
    let (server, mut s) = build_server(8, 16, 77);
    let plan = server.model.plan;
    let mut rows = Vec::new();
    for b in [2usize, 4, 8.min(plan.groups)] {
        let cts: Vec<_> = (0..b)
            .map(|i| s.client.encrypt_input(&s.ctx, &s.enc, &server.model, &s.xs[i]))
            .collect();
        let mut ev_legacy = Evaluator::new(s.ctx.clone());
        let _ = server.eval_batch_reference(&mut ev_legacy, &s.enc, &cts, &s.rlk, &s.gk);
        let legacy_rot = ev_legacy.counts.rotate;
        let mut ev_folded = Evaluator::new(s.ctx.clone());
        let _ = server.execute(&mut ev_folded, &s.enc, &EncRequest::group(&cts), &s.rlk, &s.gk);
        let folded_rot = ev_folded.counts.rotate;
        let saving = (plan.c * (b - 1)) as u64;
        assert_eq!(
            legacy_rot - folded_rot,
            saving,
            "B={b}: fold must save exactly C·(B−1) rotations"
        );
        assert_eq!(
            server.schedule(b, true).predicted_rotations(),
            folded_rot,
            "B={b}: dry-run rotation prediction drift"
        );
        rows.push(vec![
            format!("{b}"),
            format!("{legacy_rot}"),
            format!("{folded_rot}"),
            format!("{saving}"),
        ]);
    }
    print_metric_table(
        &format!(
            "Extraction fold (C={} classes, {} groups/ct) — rotations per batch",
            plan.c, plan.groups
        ),
        &["B", "legacy eval+extract", "folded schedule", "saved = C·(B−1)"],
        &rows,
    );
    println!("\nFolded responses are slot-addressed (EncScores.slot = g·reduce_span);");
    println!("the extraction rotation is composed into the read, not executed.");

    // ---- FuseMulRescale pass: op-count delta + bit-identity --------
    let server_raw = HrfServer::with_passes(server.model.clone(), PassPipeline::empty());
    let mut rows = Vec::new();
    for b in [1usize, 4] {
        let raw = HrfSchedule::compile(&server.model, b, true);
        let fused = raw.clone().optimize(PassPipeline::standard().passes());
        let rc = raw.predicted_counts().total();
        let fc = fused.predicted_counts().total();
        // The pass fuses exactly layer 3's C pairs: schedule shrinks
        // by C ops, mul_plain and rescale each drop by C, and the
        // semantic aggregates are untouched.
        assert_eq!(raw.ops.len() - fused.ops.len(), plan.c);
        assert_eq!(fc.fused_mul_rescale, plan.c as u64);
        assert_eq!(rc.mul_plain - fc.mul_plain, plan.c as u64);
        assert_eq!(rc.rescale - fc.rescale, plan.c as u64);
        assert_eq!(rc.multiplications(), fc.multiplications());
        assert_eq!(rc.rescales(), fc.rescales());
        assert_eq!(rc.rotate, fc.rotate);
        rows.push(vec![
            format!("{b}"),
            format!("{}", raw.ops.len()),
            format!("{}", fused.ops.len()),
            format!("{} / {}", rc.mul_plain, fc.mul_plain),
            format!("{} / {}", rc.rescale, fc.rescale),
            format!("{}", fc.fused_mul_rescale),
        ]);
    }
    print_metric_table(
        &format!("FuseMulRescale pass (C={} fused pairs per schedule)", plan.c),
        &[
            "B",
            "ops raw",
            "ops fused",
            "mul_pt raw/fused",
            "rescale raw/fused",
            "fused ops",
        ],
        &rows,
    );

    // Measured bit-identity: the default (fused) server and a no-pass
    // server produce identical ciphertext bits for the same input.
    let ct = s.client.encrypt_input(&s.ctx, &s.enc, &server.model, &s.xs[0]);
    let mut ev_a = Evaluator::new(s.ctx.clone());
    let outs_a = server
        .execute(&mut ev_a, &s.enc, &EncRequest::single(&ct), &s.rlk, &s.gk)
        .into_class_scores();
    let mut ev_b = Evaluator::new(s.ctx.clone());
    let outs_b = server_raw
        .execute(&mut ev_b, &s.enc, &EncRequest::single(&ct), &s.rlk, &s.gk)
        .into_class_scores();
    for (a, b) in outs_a.iter().zip(&outs_b) {
        assert_eq!(a.level, b.level);
        assert_eq!(a.scale.to_bits(), b.scale.to_bits());
        assert_eq!(a.c0.data(), b.c0.data(), "fusion changed c0 bits");
        assert_eq!(a.c1.data(), b.c1.data(), "fusion changed c1 bits");
    }
    assert_eq!(
        ev_a.counts.fused_mul_rescale,
        plan.c as u64,
        "fused execution books C fused ops"
    );
    assert_eq!(ev_b.counts.fused_mul_rescale, 0);
    assert_eq!(ev_a.counts.multiplications(), ev_b.counts.multiplications());
    println!(
        "\nFuseMulRescale: bit-identical execution; {} standalone rescales + {} standalone",
        ev_a.counts.rescale, ev_a.counts.mul_plain
    );
    println!(
        "mul_plains on the fused path vs {} + {} unfused (Δ = C = {} re-booked as fused ops).",
        ev_b.counts.rescale,
        ev_b.counts.mul_plain,
        plan.c
    );
}
