//! E1 — Table 1 reproduction: homomorphic op counts per HRF linear
//! layer, measured from the evaluator's counters and compared with the
//! paper's closed forms, sweeping K, L and C.
//!
//! Paper formulas:  L1 (1, 0, 0) · L2 (K, K, K) · L3 (C⌈log₂L(2K−1)⌉, C, C⌈log₂L(2K−1)⌉)
//! Note: our Algorithm 1 skips the identity rotation (j = 0), so the
//! measured L2 rotation count is K−1 — one fewer than the paper's K.
//! L3 additions include the C bias additions (paper counts reductions
//! only).

use cryptotree::bench_harness::print_metric_table;
use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::data::adult;
use cryptotree::forest::tree::TreeConfig;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;

fn measure(k: usize, l: usize) -> [(u64, u64, u64); 3] {
    let depth = k.trailing_zeros() as usize; // K = 2^depth
    let ds = adult::generate(1_200, 900 + k as u64);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: l,
            tree: TreeConfig {
                max_depth: depth,
                ..Default::default()
            },
            ..Default::default()
        },
        901,
    );
    // Pad every tree to exactly the sweep K (NeuralTree handles dead
    // leaves/comparisons), bypassing the forest's automatic K choice.
    let trees: Vec<_> = rf
        .trees
        .iter()
        .map(|t| cryptotree::nrf::NeuralTree::from_tree(t, k))
        .collect();
    let nf = NeuralForest {
        trees,
        alphas: rf.alphas.clone(),
        k,
        n_classes: rf.n_classes,
        activation: Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    };
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
    let plan = model.plan;
    let mut kg = KeyGenerator::new(&ctx, 902);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
    let mut client = HrfClient::new(Encryptor::new(pk, 903), Decryptor::new(kg.secret_key()));
    let server = HrfServer::new(model);
    let mut ev = Evaluator::new(ctx.clone());
    let ct = client.encrypt_input(&ctx, &enc, &server.model, &ds.x[0]);
    let (_, counts) = server.eval(&mut ev, &enc, &ct, &rlk, &gk);
    counts.table1_rows()
}

fn main() {
    let mut rows = Vec::new();
    for (k, l) in [(8usize, 16usize), (8, 64), (16, 16), (16, 64), (32, 16)] {
        let plan = cryptotree::hrf::HrfPlan::new(k, l, 2, 14, 4096).unwrap();
        let formulas = plan.table1_formulas();
        let measured = measure(k, l);
        for (i, layer) in ["L1", "L2", "L3"].iter().enumerate() {
            let (fa, fm, fr) = formulas[i];
            let (ma, mm, mr) = measured[i];
            rows.push(vec![
                format!("K={k} L={l}"),
                layer.to_string(),
                format!("{fa} / {ma}"),
                format!("{fm} / {mm}"),
                format!("{fr} / {mr}"),
            ]);
        }
        // Invariants the paper's Table 1 asserts:
        assert_eq!(measured[0], (1, 0, 0), "L1 shape");
        assert_eq!(measured[1].1, k as u64, "L2 multiplications = K");
        assert_eq!(measured[1].2, (k - 1) as u64, "L2 rotations = K-1 (identity skipped)");
        assert_eq!(measured[2].1, 2, "L3 multiplications = C");
    }
    print_metric_table(
        "Table 1 — op counts per linear layer: paper formula / measured",
        &["plan", "layer", "additions", "multiplications", "rotations"],
        &rows,
    );
    println!("\nL2 rotations: measured K-1 (identity rotation skipped); paper counts K.");
    println!("L3 additions: measured includes the C bias additions.");
    println!("Key property (paper §3): costs depend on K and C only — compare L=16 vs L=64 rows.");
}
