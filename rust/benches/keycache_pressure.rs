//! Keycache pressure: session count × batch size B against a fixed
//! key-byte budget — the memory/throughput trade-off behind the
//! ROADMAP's "Sharded Galois-key cache" item.
//!
//! Real key material is generated once per B to get *exact*
//! `key_bytes` footprints (batched sessions need
//! `rotations_needed_batched(B)` Galois keys — roughly 2(B−1) more
//! switching keys than single-sample sessions). The overcommit sweep
//! then stores synthetic entries of those exact sizes, so thousands of
//! sessions can be modelled without allocating gigabytes of real keys.
//!
//! Reported per (B, sessions/budget overcommit):
//! * resident MiB vs budget (never exceeds it),
//! * registrations/sec through the sharded cache,
//! * steady-state hit rate for a cycling (LRU-adversarial) and a
//!   hot-set access pattern,
//! * the implied re-registration traffic (misses × session MiB) —
//!   the price of shrinking the budget.

//! The spill-tier section re-runs the LRU-adversarial cycle with the
//! disk tier enabled: every RAM miss becomes a transparent reload
//! instead of a client re-registration. Reported: spill hit rate,
//! mean reload latency, and the re-upload bandwidth the tier saves vs
//! the spill-disabled cache at the same overcommit. Records land in
//! `BENCH_keycache_pressure.json` via the bench harness.

use cryptotree::bench_harness::{bench, print_metric_table, write_json, BenchRecord};
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, KeyGenerator};
use cryptotree::hrf::HrfPlan;
use cryptotree::keycache::{KeyCache, KeyCacheConfig, SpillCodec, SpillConfig};
use std::sync::Arc;

/// Bench codec: payloads padded to the session's exact key size, so
/// spill-file traffic models real key-upload bandwidth without
/// holding real keys for thousands of synthetic sessions.
struct PaddedCodec {
    bytes: usize,
}

impl SpillCodec<u64> for PaddedCodec {
    fn encode(&self, value: &u64) -> Vec<u8> {
        let mut p = vec![0u8; self.bytes.max(8)];
        p[..8].copy_from_slice(&value.to_le_bytes());
        p
    }
    fn decode(&self, _id: u64, bytes: &[u8]) -> Option<u64> {
        bytes.get(..8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }
    fn size_bytes(&self, _value: &u64) -> usize {
        self.bytes.max(8)
    }
}

fn main() {
    // Key footprints on a cheap ring (N=4096, depth 4): the *relative*
    // cost of B is ring-independent, the absolute MiB are printed.
    let params = Arc::new(CkksParams::build("keycache-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let plan = HrfPlan::new(8, 16, 2, 14, params.slots()).unwrap();
    let b_max = plan.groups;
    println!(
        "plan: K={} L={} | span {}, {} sample groups/ct",
        plan.k, plan.l, plan.reduce_span, b_max
    );

    let mut kg = KeyGenerator::new(&ctx, 7);
    let rlk = kg.gen_relin_key(&ctx);
    let mut session_bytes = Vec::new(); // (b, bytes, n_galois)
    for b in [1usize, b_max] {
        let rots = plan.rotations_needed_batched(b);
        let gk = kg.gen_galois_keys(&ctx, &rots);
        session_bytes.push((b, rlk.key_bytes() + gk.key_bytes(), rots.len()));
    }
    let rows: Vec<Vec<String>> = session_bytes
        .iter()
        .map(|&(b, bytes, n_rots)| {
            vec![
                b.to_string(),
                n_rots.to_string(),
                format!("{:.2}", bytes as f64 / (1024.0 * 1024.0)),
            ]
        })
        .collect();
    print_metric_table(
        "per-session key footprint (exact key_bytes, relin + Galois)",
        &["B", "galois keys", "session MiB"],
        &rows,
    );

    // ---- Overcommit sweep against a fixed budget -------------------
    // Budget sized to admit ~64 single-sample sessions; batched
    // sessions are bigger, so the same budget admits fewer of them.
    let budget = 64 * session_bytes[0].1 as u64;
    let mut rows = Vec::new();
    for &(b, bytes, _) in &session_bytes {
        let admitted = (budget / bytes as u64).max(1);
        for overcommit in [1u64, 2, 4] {
            let n_sessions = admitted * overcommit;

            // Registration throughput: fill a fresh cache each iter.
            let reg = bench(
                &format!("register B={b} n={n_sessions}"),
                1,
                5,
                || {
                    let cache: KeyCache<u64> = KeyCache::new(KeyCacheConfig {
                        num_shards: 16,
                        budget_bytes: budget,
                    });
                    for id in 0..n_sessions {
                        cache.insert(id, id, bytes);
                    }
                    assert!(cache.resident_bytes() <= budget, "budget violated");
                    cache
                },
            );

            // Steady-state cache for the access-pattern measurements.
            let cache: KeyCache<u64> = KeyCache::new(KeyCacheConfig {
                num_shards: 16,
                budget_bytes: budget,
            });
            for id in 0..n_sessions {
                cache.insert(id, id, bytes);
            }
            let resident = cache.resident_bytes();

            // Cycling over every registered session: the worst case
            // for LRU once the working set exceeds the budget.
            let s0 = cache.stats().snapshot();
            let lookups = 4 * n_sessions;
            let cyc = bench(&format!("cycle B={b} n={n_sessions}"), 1, 3, || {
                for i in 0..lookups {
                    let _ = cache.lookup(i % n_sessions);
                }
            });
            let s1 = cache.stats().snapshot();
            let cyc_hits = s1.hits - s0.hits;
            let cyc_total = (s1.hits + s1.misses) - (s0.hits + s0.misses);

            // Hot set: the most recent `admitted` sessions — the
            // workload the budget was sized for.
            let hot_lo = n_sessions - admitted.min(n_sessions);
            for i in hot_lo..n_sessions {
                let _ = cache.lookup(i); // warm residency
            }
            let s2 = cache.stats().snapshot();
            for _ in 0..4 {
                for i in hot_lo..n_sessions {
                    let _ = cache.lookup(i);
                }
            }
            let s3 = cache.stats().snapshot();
            let hot_hits = s3.hits - s2.hits;
            let hot_total = (s3.hits + s3.misses) - (s2.hits + s2.misses);

            let cyc_miss_rate = 1.0 - cyc_hits as f64 / cyc_total.max(1) as f64;
            rows.push(vec![
                b.to_string(),
                n_sessions.to_string(),
                format!("{overcommit}x"),
                format!(
                    "{:.1}/{:.1}",
                    resident as f64 / (1024.0 * 1024.0),
                    budget as f64 / (1024.0 * 1024.0)
                ),
                format!("{:.0}", reg.throughput(n_sessions as f64)),
                format!("{:.0}", cyc.throughput(lookups as f64)),
                format!("{:.0}%", 100.0 * cyc_hits as f64 / cyc_total.max(1) as f64),
                format!("{:.0}%", 100.0 * hot_hits as f64 / hot_total.max(1) as f64),
                format!(
                    "{:.1}",
                    cyc_miss_rate * bytes as f64 / (1024.0 * 1024.0)
                        * cyc.throughput(lookups as f64)
                ),
            ]);
        }
    }
    print_metric_table(
        &format!(
            "overcommit sweep — fixed budget {:.1} MiB, 16 shards",
            budget as f64 / (1024.0 * 1024.0)
        ),
        &[
            "B",
            "sessions",
            "overcommit",
            "resident/budget MiB",
            "reg/s",
            "lookup/s",
            "cycle hit",
            "hot hit",
            "rereg MiB/s",
        ],
        &rows,
    );
    println!("\ncycle = round-robin over ALL registered sessions (LRU-adversarial);");
    println!("hot   = only the most recent budget-sized working set.");
    println!("rereg MiB/s = miss rate x session MiB x lookup rate: the key re-upload");
    println!("bandwidth a too-small budget converts cache misses into.");

    // ---- Spill tier: disk absorbs the overcommit -------------------
    // Same LRU-adversarial cycle at 2x overcommit, now with the disk
    // tier holding the overflow: evictions demote to files, RAM
    // misses reload transparently instead of rejecting the session.
    let spill_root = std::env::temp_dir().join(format!(
        "cryptotree-keycache-bench-{}",
        std::process::id()
    ));
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rows = Vec::new();
    for &(b, bytes, _) in &session_bytes {
        let admitted = (budget / bytes as u64).max(1);
        let n_sessions = admitted * 2;
        let lookups = 2 * n_sessions;

        // Baseline: spill disabled — every cycle miss is a forced
        // client re-registration (insert of `bytes`).
        let plain: KeyCache<u64> = KeyCache::new(KeyCacheConfig {
            num_shards: 16,
            budget_bytes: budget,
        });
        for id in 0..n_sessions {
            plain.insert(id, id, bytes);
        }
        let p0 = plain.stats().snapshot();
        let base = bench(&format!("cycle+rereg B={b} n={n_sessions}"), 1, 3, || {
            for i in 0..lookups {
                let id = i % n_sessions;
                if !plain.lookup(id).is_resident() {
                    plain.insert(id, id, bytes); // the re-upload
                }
            }
        });
        let p1 = plain.stats().snapshot();
        let rereg = p1.misses - p0.misses;
        // Per-iteration rate: the stats deltas span 1 warmup + 3
        // timed runs, the median times one run.
        let rereg_mib_s = (rereg as f64 / 4.0) * bytes as f64 / (1024.0 * 1024.0)
            / base.median.as_secs_f64();

        // Spill enabled: the identical cycle, zero re-registrations.
        let dir = spill_root.join(format!("b{b}"));
        let cache: KeyCache<u64> = KeyCache::new(KeyCacheConfig {
            num_shards: 16,
            budget_bytes: budget,
        });
        cache
            .enable_spill(
                SpillConfig {
                    dir: dir.clone(),
                    budget_bytes: 4 * budget,
                },
                Box::new(PaddedCodec { bytes }),
            )
            .expect("spill dir");
        for id in 0..n_sessions {
            cache.insert(id, id, bytes);
        }
        let s0 = cache.stats().snapshot();
        let cyc = bench(&format!("spill cycle B={b} n={n_sessions}"), 1, 3, || {
            for i in 0..lookups {
                assert!(
                    cache.lookup(i % n_sessions).is_resident(),
                    "spill tier must absorb every cycle miss"
                );
            }
        });
        let s1 = cache.stats().snapshot();
        let reloads = s1.spill_hits - s0.spill_hits;
        let failed = s1.spill_misses - s0.spill_misses;
        let hit_rate = reloads as f64 / (reloads + failed).max(1) as f64;
        // Reloads dominate the cycle (a resident hit is a hash probe),
        // so median-iter-time / reloads-per-iter approximates one
        // reload's latency: read + decode + promote + demote a victim.
        let reloads_per_iter = reloads as f64 / 4.0; // 1 warmup + 3 timed
        let reload_us = if reloads_per_iter > 0.0 {
            cyc.median.as_secs_f64() * 1e6 / reloads_per_iter
        } else {
            0.0
        };
        // Bandwidth the tier keeps off the wire: every reload is a
        // re-registration (session MiB of key upload) that no longer
        // happens.
        let saved_mib_s = (reloads_per_iter * bytes as f64 / (1024.0 * 1024.0))
            / cyc.median.as_secs_f64();

        rows.push(vec![
            b.to_string(),
            n_sessions.to_string(),
            format!("{:.0}%", 100.0 * hit_rate),
            format!("{:.1}", reload_us),
            format!("{:.1}", cyc.throughput(lookups as f64)),
            format!("{:.1}", saved_mib_s),
            format!("{:.1}", rereg_mib_s),
        ]);
        records.push(BenchRecord::from_timing(
            &cyc,
            1,
            &format!("B={b} sessions={n_sessions} spill=on budget={budget}"),
        ));
        records.push(BenchRecord::from_timing(
            &base,
            1,
            &format!("B={b} sessions={n_sessions} spill=off budget={budget}"),
        ));
        std::fs::remove_dir_all(&dir).ok();
    }
    print_metric_table(
        "spill tier — 2x overcommit, LRU-adversarial cycle",
        &[
            "B",
            "sessions",
            "spill hit",
            "reload µs",
            "lookup/s",
            "saved MiB/s",
            "rereg MiB/s (no spill)",
        ],
        &rows,
    );
    println!("\nsaved MiB/s = key-upload bandwidth the disk tier absorbs (each reload");
    println!("replaces one full re-registration); the no-spill column is the same");
    println!("cycle paying that bandwidth as client re-uploads instead.");
    std::fs::remove_dir_all(&spill_root).ok();
    write_json("BENCH_keycache_pressure.json", &records).ok();
}
