//! Keycache pressure: session count × batch size B against a fixed
//! key-byte budget — the memory/throughput trade-off behind the
//! ROADMAP's "Sharded Galois-key cache" item.
//!
//! Real key material is generated once per B to get *exact*
//! `key_bytes` footprints (batched sessions need
//! `rotations_needed_batched(B)` Galois keys — roughly 2(B−1) more
//! switching keys than single-sample sessions). The overcommit sweep
//! then stores synthetic entries of those exact sizes, so thousands of
//! sessions can be modelled without allocating gigabytes of real keys.
//!
//! Reported per (B, sessions/budget overcommit):
//! * resident MiB vs budget (never exceeds it),
//! * registrations/sec through the sharded cache,
//! * steady-state hit rate for a cycling (LRU-adversarial) and a
//!   hot-set access pattern,
//! * the implied re-registration traffic (misses × session MiB) —
//!   the price of shrinking the budget.

use cryptotree::bench_harness::{bench, print_metric_table};
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, KeyGenerator};
use cryptotree::hrf::HrfPlan;
use cryptotree::keycache::{KeyCache, KeyCacheConfig};
use std::sync::Arc;

fn main() {
    // Key footprints on a cheap ring (N=4096, depth 4): the *relative*
    // cost of B is ring-independent, the absolute MiB are printed.
    let params = Arc::new(CkksParams::build("keycache-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let plan = HrfPlan::new(8, 16, 2, 14, params.slots()).unwrap();
    let b_max = plan.groups;
    println!(
        "plan: K={} L={} | span {}, {} sample groups/ct",
        plan.k, plan.l, plan.reduce_span, b_max
    );

    let mut kg = KeyGenerator::new(&ctx, 7);
    let rlk = kg.gen_relin_key(&ctx);
    let mut session_bytes = Vec::new(); // (b, bytes, n_galois)
    for b in [1usize, b_max] {
        let rots = plan.rotations_needed_batched(b);
        let gk = kg.gen_galois_keys(&ctx, &rots);
        session_bytes.push((b, rlk.key_bytes() + gk.key_bytes(), rots.len()));
    }
    let rows: Vec<Vec<String>> = session_bytes
        .iter()
        .map(|&(b, bytes, n_rots)| {
            vec![
                b.to_string(),
                n_rots.to_string(),
                format!("{:.2}", bytes as f64 / (1024.0 * 1024.0)),
            ]
        })
        .collect();
    print_metric_table(
        "per-session key footprint (exact key_bytes, relin + Galois)",
        &["B", "galois keys", "session MiB"],
        &rows,
    );

    // ---- Overcommit sweep against a fixed budget -------------------
    // Budget sized to admit ~64 single-sample sessions; batched
    // sessions are bigger, so the same budget admits fewer of them.
    let budget = 64 * session_bytes[0].1 as u64;
    let mut rows = Vec::new();
    for &(b, bytes, _) in &session_bytes {
        let admitted = (budget / bytes as u64).max(1);
        for overcommit in [1u64, 2, 4] {
            let n_sessions = admitted * overcommit;

            // Registration throughput: fill a fresh cache each iter.
            let reg = bench(
                &format!("register B={b} n={n_sessions}"),
                1,
                5,
                || {
                    let cache: KeyCache<u64> = KeyCache::new(KeyCacheConfig {
                        num_shards: 16,
                        budget_bytes: budget,
                    });
                    for id in 0..n_sessions {
                        cache.insert(id, id, bytes);
                    }
                    assert!(cache.resident_bytes() <= budget, "budget violated");
                    cache
                },
            );

            // Steady-state cache for the access-pattern measurements.
            let cache: KeyCache<u64> = KeyCache::new(KeyCacheConfig {
                num_shards: 16,
                budget_bytes: budget,
            });
            for id in 0..n_sessions {
                cache.insert(id, id, bytes);
            }
            let resident = cache.resident_bytes();

            // Cycling over every registered session: the worst case
            // for LRU once the working set exceeds the budget.
            let s0 = cache.stats().snapshot();
            let lookups = 4 * n_sessions;
            let cyc = bench(&format!("cycle B={b} n={n_sessions}"), 1, 3, || {
                for i in 0..lookups {
                    let _ = cache.lookup(i % n_sessions);
                }
            });
            let s1 = cache.stats().snapshot();
            let cyc_hits = s1.hits - s0.hits;
            let cyc_total = (s1.hits + s1.misses) - (s0.hits + s0.misses);

            // Hot set: the most recent `admitted` sessions — the
            // workload the budget was sized for.
            let hot_lo = n_sessions - admitted.min(n_sessions);
            for i in hot_lo..n_sessions {
                let _ = cache.lookup(i); // warm residency
            }
            let s2 = cache.stats().snapshot();
            for _ in 0..4 {
                for i in hot_lo..n_sessions {
                    let _ = cache.lookup(i);
                }
            }
            let s3 = cache.stats().snapshot();
            let hot_hits = s3.hits - s2.hits;
            let hot_total = (s3.hits + s3.misses) - (s2.hits + s2.misses);

            let cyc_miss_rate = 1.0 - cyc_hits as f64 / cyc_total.max(1) as f64;
            rows.push(vec![
                b.to_string(),
                n_sessions.to_string(),
                format!("{overcommit}x"),
                format!(
                    "{:.1}/{:.1}",
                    resident as f64 / (1024.0 * 1024.0),
                    budget as f64 / (1024.0 * 1024.0)
                ),
                format!("{:.0}", reg.throughput(n_sessions as f64)),
                format!("{:.0}", cyc.throughput(lookups as f64)),
                format!("{:.0}%", 100.0 * cyc_hits as f64 / cyc_total.max(1) as f64),
                format!("{:.0}%", 100.0 * hot_hits as f64 / hot_total.max(1) as f64),
                format!(
                    "{:.1}",
                    cyc_miss_rate * bytes as f64 / (1024.0 * 1024.0)
                        * cyc.throughput(lookups as f64)
                ),
            ]);
        }
    }
    print_metric_table(
        &format!(
            "overcommit sweep — fixed budget {:.1} MiB, 16 shards",
            budget as f64 / (1024.0 * 1024.0)
        ),
        &[
            "B",
            "sessions",
            "overcommit",
            "resident/budget MiB",
            "reg/s",
            "lookup/s",
            "cycle hit",
            "hot hit",
            "rereg MiB/s",
        ],
        &rows,
    );
    println!("\ncycle = round-robin over ALL registered sessions (LRU-adversarial);");
    println!("hot   = only the most recent budget-sized working set.");
    println!("rereg MiB/s = miss rate x session MiB x lookup rate: the key re-upload");
    println!("bandwidth a too-small budget converts cache misses into.");
}
