//! E6 — CKKS primitive microbenchmarks (the §Perf working set):
//! NTT, encode/decode, encrypt/decrypt, add, ct×pt, ct×ct (+relin),
//! rescale, rotation, and the two polynomial-evaluation strategies.

use cryptotree::bench_harness::{bench, print_table};
use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::ntt::NttTable;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::rng::Xoshiro256pp;

fn main() {
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let mut kg = KeyGenerator::new(&ctx, 71);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &[1]);
    let mut encryptor = Encryptor::new(pk, 72);
    let decryptor = Decryptor::new(kg.secret_key());
    let mut ev = Evaluator::new(ctx.clone());
    let mut rng = Xoshiro256pp::new(73);
    let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();

    let mut rows = Vec::new();

    // Raw NTT on one limb.
    let table = NttTable::new(ctx.q(0), ctx.n());
    let mut poly: Vec<u64> = (0..ctx.n()).map(|_| rng.next_below(ctx.q(0))).collect();
    rows.push(bench(&format!("ntt forward (N={})", ctx.n()), 3, 20, || {
        table.forward(&mut poly);
    }));
    rows.push(bench("ntt inverse", 3, 20, || table.inverse(&mut poly)));

    rows.push(bench("encode (full slots)", 2, 10, || {
        enc.encode(&ctx, &z, params.max_level(), params.scale)
    }));
    let pt = enc.encode(&ctx, &z, params.max_level(), params.scale);
    rows.push(bench("decode", 2, 10, || enc.decode(&ctx, &pt)));
    rows.push(bench("encrypt", 2, 10, || encryptor.encrypt(&ctx, &pt)));
    let ct = encryptor.encrypt(&ctx, &pt);
    rows.push(bench("decrypt+decode", 2, 10, || {
        decryptor.decrypt_slots(&ctx, &enc, &ct)
    }));
    rows.push(bench("add (ct+ct)", 3, 20, || ev.add(&ct, &ct)));
    rows.push(bench("mul_plain (ct*pt)", 3, 20, || ev.mul_plain(&ct, &pt)));
    rows.push(bench("mul+relin (ct*ct)", 1, 8, || ev.mul(&ct, &ct, &rlk)));
    rows.push(bench("square+relin", 1, 8, || ev.square(&ct, &rlk)));
    rows.push(bench("rotate(1)", 1, 8, || ev.rotate(&ct, 1, &gk)));
    rows.push(bench("rescale", 2, 10, || {
        let mut c = ct.clone();
        ev.rescale(&mut c);
        c
    }));
    let coeffs = cryptotree::nrf::activation::chebyshev_fit_tanh(3.0, 4);
    rows.push(bench("poly deg4 (horner)", 1, 4, || {
        ev.eval_poly_horner(&enc, &ct, &coeffs, &rlk)
    }));
    rows.push(bench("poly deg4 (power basis)", 1, 4, || {
        ev.eval_poly_power_basis(&enc, &ct, &coeffs, &rlk)
    }));

    print_table(
        &format!("CKKS primitives — {} (depth {})", params.name, params.depth()),
        &rows,
    );
}
