//! E6 — CKKS primitive microbenchmarks (the §Perf working set):
//! NTT, encode/decode, encrypt/decrypt, add, ct×pt, ct×ct (+relin),
//! rescale, rotation, and the two polynomial-evaluation strategies,
//! plus a limb-parallel worker sweep over the key-switch-heavy ops.
//!
//! Emits `BENCH_ckks_primitives.json` — (op, ns/op, threads, params)
//! records — so the perf trajectory is tracked across PRs (see
//! ROADMAP.md §Benchmarking).

use cryptotree::bench_harness::{bench, print_table, write_json, BenchRecord, Timing};
use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::ntt::NttTable;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::rng::Xoshiro256pp;

fn main() {
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let mut kg = KeyGenerator::new(&ctx, 71);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &[1]);
    let mut encryptor = Encryptor::new(pk, 72);
    let decryptor = Decryptor::new(kg.secret_key());
    let mut ev = Evaluator::new(ctx.clone());
    let mut rng = Xoshiro256pp::new(73);
    let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();

    let mut rows: Vec<Timing> = Vec::new();
    let mut records: Vec<BenchRecord> = Vec::new();
    let push = |rows: &mut Vec<Timing>, records: &mut Vec<BenchRecord>, t: Timing, w: usize| {
        records.push(BenchRecord::from_timing(&t, w, params.name));
        rows.push(t);
    };

    // Raw NTT on one limb.
    let table = NttTable::new(ctx.q(0), ctx.n());
    let mut poly: Vec<u64> = (0..ctx.n()).map(|_| rng.next_below(ctx.q(0))).collect();
    let t = bench(&format!("ntt forward (N={})", ctx.n()), 3, 20, || {
        table.forward(&mut poly);
    });
    push(&mut rows, &mut records, t, 1);
    let t = bench("ntt inverse", 3, 20, || table.inverse(&mut poly));
    push(&mut rows, &mut records, t, 1);

    let t = bench("encode (full slots)", 2, 10, || {
        enc.encode(&ctx, &z, params.max_level(), params.scale)
    });
    push(&mut rows, &mut records, t, 1);
    let pt = enc.encode(&ctx, &z, params.max_level(), params.scale);
    let t = bench("decode", 2, 10, || enc.decode(&ctx, &pt));
    push(&mut rows, &mut records, t, 1);
    let t = bench("encrypt", 2, 10, || encryptor.encrypt(&ctx, &pt));
    push(&mut rows, &mut records, t, 1);
    let ct = encryptor.encrypt(&ctx, &pt);
    let t = bench("decrypt+decode", 2, 10, || {
        decryptor.decrypt_slots(&ctx, &enc, &ct)
    });
    push(&mut rows, &mut records, t, 1);
    let t = bench("add (ct+ct)", 3, 20, || ev.add(&ct, &ct));
    push(&mut rows, &mut records, t, 1);
    let t = bench("mul_plain (ct*pt)", 3, 20, || ev.mul_plain(&ct, &pt));
    push(&mut rows, &mut records, t, 1);

    // The key-switch-heavy ops and the Barrett/Shoup kernels, swept
    // over the limb-parallel worker count (1 = serial baseline; the
    // ≥2× single-thread targets in ISSUE 5 read the w=1 rows).
    for &w in &[1usize, 2, 4] {
        ctx.set_workers(w);
        let t = bench(&format!("mul+relin (ct*ct) [w={w}]"), 1, 8, || {
            ev.mul(&ct, &ct, &rlk)
        });
        push(&mut rows, &mut records, t, w);
        let t = bench(&format!("square+relin [w={w}]"), 1, 8, || {
            ev.square(&ct, &rlk)
        });
        push(&mut rows, &mut records, t, w);
        let t = bench(&format!("rotate(1) [w={w}]"), 1, 8, || ev.rotate(&ct, 1, &gk));
        push(&mut rows, &mut records, t, w);
        let t = bench(&format!("hoist [w={w}]"), 1, 8, || ev.hoist(&ct));
        push(&mut rows, &mut records, t, w);
        let digits = ev.hoist(&ct);
        let t = bench(&format!("rotate_hoisted(1) [w={w}]"), 1, 8, || {
            ev.rotate_hoisted(&ct, &digits, 1, &gk)
        });
        push(&mut rows, &mut records, t, w);
        let t = bench(&format!("rescale [w={w}]"), 2, 10, || {
            let mut c = ct.clone();
            ev.rescale(&mut c);
            c
        });
        push(&mut rows, &mut records, t, w);
        let t = bench(&format!("mul_plain_rescale (fused) [w={w}]"), 2, 10, || {
            ev.mul_plain_rescale(&ct, &pt)
        });
        push(&mut rows, &mut records, t, w);
    }
    ctx.set_workers(1);

    let coeffs = cryptotree::nrf::activation::chebyshev_fit_tanh(3.0, 4);
    let t = bench("poly deg4 (horner)", 1, 4, || {
        ev.eval_poly_horner(&enc, &ct, &coeffs, &rlk)
    });
    push(&mut rows, &mut records, t, 1);
    let t = bench("poly deg4 (power basis)", 1, 4, || {
        ev.eval_poly_power_basis(&enc, &ct, &coeffs, &rlk)
    });
    push(&mut rows, &mut records, t, 1);

    print_table(
        &format!("CKKS primitives — {} (depth {})", params.name, params.depth()),
        &rows,
    );
    write_json("BENCH_ckks_primitives.json", &records).expect("write bench json");
}
