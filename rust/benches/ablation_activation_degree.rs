//! A1 — ablation over the activation-polynomial degree m (the paper's
//! key approximation knob, §3): fit quality, plaintext accuracy,
//! NRF(tanh)/NRF(poly) agreement, and the multiplicative depth the HRF
//! needs — the trade-off that motivates the paper's low-degree choice.

use cryptotree::bench_harness::print_metric_table;
use cryptotree::data::adult;
use cryptotree::forest::metrics::{agreement, Metrics};
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, fit_error, Activation};
use cryptotree::nrf::{finetune_last_layer, FinetuneConfig, NeuralForest};

/// Levels the power-basis evaluation of a degree-m polynomial consumes
/// (x^2..x^m via squarings/mults = ⌈log2 m⌉, +1 coefficient multiply).
fn act_levels(m: usize) -> usize {
    (usize::BITS - (m.max(2) - 1).leading_zeros()) as usize + 1
}

fn main() {
    let a = 3.0;
    let ds = adult::generate(8_000, 51);
    let (train, valid) = ds.split(0.8, 52);
    let rf = RandomForest::fit(
        &train,
        &RandomForestConfig {
            n_trees: 24,
            ..Default::default()
        },
        53,
    );
    let mut nf_tanh = NeuralForest::from_forest(&rf, Activation::Tanh { a });
    finetune_last_layer(&mut nf_tanh, &train, &FinetuneConfig::default(), 54);
    let tanh_pred = nf_tanh.predict_batch(&valid.x);
    let m_tanh = Metrics::from_predictions(&tanh_pred, &valid.y);

    let mut rows = Vec::new();
    for degree in [2usize, 3, 4, 5, 6, 8] {
        let coeffs = chebyshev_fit_tanh(a, degree);
        let err = fit_error(a, &coeffs, 400);
        let nf_poly = nf_tanh.with_activation(Activation::Poly { coeffs });
        let poly_pred = nf_poly.predict_batch(&valid.x);
        let m_poly = Metrics::from_predictions(&poly_pred, &valid.y);
        let agree = agreement(&poly_pred, &tanh_pred);
        // HRF depth: two activations + two plaintext muls.
        let depth = 2 * act_levels(degree) + 2;
        rows.push(vec![
            degree.to_string(),
            format!("{err:.4}"),
            format!("{:.3}", m_poly.accuracy),
            format!("{:.1}%", 100.0 * agree),
            depth.to_string(),
            if depth <= 8 { "fits d=8 chain".into() } else { format!("needs depth {depth}") },
        ]);
    }
    print_metric_table(
        &format!(
            "Ablation — activation degree (tanh a={a}; NRF-tanh accuracy {:.3})",
            m_tanh.accuracy
        ),
        &["degree", "max fit err", "poly accuracy", "agree vs tanh", "HRF depth", "params"],
        &rows,
    );
    println!("\nHigher degree → better tanh fit and agreement, but more CKKS levels");
    println!("(bigger N, slower ops). Degree 4 is the sweet spot for the depth-8 chain.");
}
