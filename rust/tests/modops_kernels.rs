//! Kernel parity for the RNS data plane (ISSUE 5):
//!
//! * The division-free Barrett/Shoup kernels must equal the
//!   `mul_mod` u128-division **oracle** on random and structured
//!   inputs, across **every** prime (chain + special) of the toy,
//!   fast and paper (`hrf_default`) parameter sets. CI runs this file
//!   under `--release` as well — the optimized kernels are the ones
//!   serving traffic, and debug-mode u128 paths can mask codegen
//!   regressions.
//! * Thread-count invariance: the limb-parallel executor must be a
//!   pure throughput knob — primitive op chains and full
//!   `HrfServer::execute` runs at worker counts 1 vs 4 produce
//!   **bit-identical** ciphertexts (`engine_parity`-style assertions).

use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::modops::{
    barrett_precompute, barrett_reduce_128, barrett_reduce_64, mul_mod, mul_mod_barrett,
    mul_mod_shoup, shoup_precompute,
};
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{Ciphertext, CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::{Activation, NeuralForest, NeuralTree};
use cryptotree::rng::Xoshiro256pp;
use std::sync::Arc;

/// Every prime of every shipped parameter set (chain + special) —
/// `hrf_default` is the paper configuration.
fn parameter_set_primes() -> Vec<(&'static str, Vec<u64>)> {
    [CkksParams::toy(), CkksParams::fast(), CkksParams::hrf_default()]
        .into_iter()
        .map(|p| {
            let mut primes = p.moduli.clone();
            primes.push(p.special);
            (p.name, primes)
        })
        .collect()
}

/// Structured edge inputs around multiples of q and the u64 extremes.
fn edge_inputs(q: u64) -> Vec<u64> {
    let mut v = vec![0u64, 1, 2, q - 1, q, q + 1, u64::MAX, u64::MAX - 1, 1 << 63];
    // largest multiple of q that fits in u64, ±1
    let k = q * (u64::MAX / q);
    v.push(k);
    v.push(k - 1);
    v.push(k + 1);
    v
}

#[test]
fn barrett_mul_matches_oracle_on_all_parameter_set_primes() {
    let mut rng = Xoshiro256pp::new(500);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            let ratio = barrett_precompute(q);
            for _ in 0..2_000 {
                let (x, y) = (rng.next_below(q), rng.next_below(q));
                assert_eq!(
                    mul_mod_barrett(x, y, q, ratio),
                    mul_mod(x, y, q),
                    "{name} q={q} x={x} y={y}"
                );
            }
            // Unreduced operands (the kernel contract allows any u64).
            for _ in 0..500 {
                let (x, y) = (rng.next_u64(), rng.next_u64());
                assert_eq!(
                    mul_mod_barrett(x, y, q, ratio),
                    mul_mod(x, y, q),
                    "{name} q={q} unreduced x={x} y={y}"
                );
            }
            for &x in &edge_inputs(q) {
                for &y in &[0u64, 1, q - 1, u64::MAX] {
                    assert_eq!(mul_mod_barrett(x, y, q, ratio), mul_mod(x, y, q));
                }
            }
        }
    }
}

#[test]
fn barrett_reduce_64_matches_mod_on_all_parameter_set_primes() {
    let mut rng = Xoshiro256pp::new(501);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            let (_, r_hi) = barrett_precompute(q);
            for _ in 0..4_000 {
                let x = rng.next_u64();
                assert_eq!(barrett_reduce_64(x, q, r_hi), x % q, "{name} q={q} x={x}");
            }
            for &x in &edge_inputs(q) {
                assert_eq!(barrett_reduce_64(x, q, r_hi), x % q, "{name} q={q} edge {x}");
            }
        }
    }
}

#[test]
fn barrett_reduce_128_matches_mod_on_all_parameter_set_primes() {
    let mut rng = Xoshiro256pp::new(502);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            let ratio = barrett_precompute(q);
            let oracle =
                |lo: u64, hi: u64| ((((hi as u128) << 64) | lo as u128) % q as u128) as u64;
            for _ in 0..2_000 {
                let (lo, hi) = (rng.next_u64(), rng.next_u64());
                assert_eq!(
                    barrett_reduce_128(lo, hi, q, ratio),
                    oracle(lo, hi),
                    "{name} q={q} lo={lo} hi={hi}"
                );
            }
            for &(lo, hi) in &[
                (0u64, 0u64),
                (q - 1, 0),
                (u64::MAX, u64::MAX),
                (0, u64::MAX),
                (u64::MAX, 0),
            ] {
                assert_eq!(barrett_reduce_128(lo, hi, q, ratio), oracle(lo, hi));
            }
            // products of near-maximal residues (the dyadic-mul shape)
            for _ in 0..500 {
                let (a, b) = (q - 1 - rng.next_below(4), q - 1 - rng.next_below(4));
                let p = a as u128 * b as u128;
                assert_eq!(
                    barrett_reduce_128(p as u64, (p >> 64) as u64, q, ratio),
                    (p % q as u128) as u64
                );
            }
        }
    }
}

#[test]
fn shoup_mul_matches_oracle_for_arbitrary_left_operand() {
    // Shoup multiplication requires only y < q; the left operand may
    // be any u64 (the lazy NTT and the CRT digit path rely on this).
    let mut rng = Xoshiro256pp::new(503);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            for _ in 0..2_000 {
                let y = rng.next_below(q);
                let ys = shoup_precompute(y, q);
                let x = rng.next_u64();
                assert_eq!(
                    mul_mod_shoup(x, y, ys, q),
                    mul_mod(x % q, y, q),
                    "{name} q={q} x={x} y={y}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Thread-count invariance
// ---------------------------------------------------------------------

fn ct_bits_equal(a: &Ciphertext, b: &Ciphertext) -> bool {
    a.level == b.level
        && a.scale.to_bits() == b.scale.to_bits()
        && a.c0.data() == b.c0.data()
        && a.c1.data() == b.c1.data()
}

#[test]
fn primitive_chain_is_worker_count_invariant() {
    let ctx = CkksContext::new(CkksParams::toy());
    let enc = Encoder::new(&ctx);
    let mut kg = KeyGenerator::new(&ctx, 504);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &[1, 2, 4]);
    let mut encryptor = Encryptor::new(pk, 505);
    let decryptor = Decryptor::new(kg.secret_key());
    let mut rng = Xoshiro256pp::new(506);
    let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let ct = encryptor.encrypt_slots(&ctx, &enc, &z);

    let run = |workers: usize| -> Vec<Ciphertext> {
        ctx.set_workers(workers);
        let mut ev = Evaluator::new(ctx.clone());
        let rot = ev.rotate(&ct, 1, &gk);
        let digits = ev.hoist(&ct);
        let hrot = ev.rotate_hoisted(&ct, &digits, 2, &gk);
        let mut prod = ev.mul(&ct, &rot, &rlk);
        ev.rescale(&mut prod);
        let mut sq = ev.square(&ct, &rlk);
        ev.rescale(&mut sq);
        let sum = ev.rotate_sum(&sq, 4, &gk);
        vec![rot, hrot, prod, sq, sum]
    };
    let serial = run(1);
    let parallel = run(4);
    ctx.set_workers(1);
    assert_eq!(serial.len(), parallel.len());
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert!(ct_bits_equal(a, b), "primitive chain output {i} differs");
    }
    // and the results are still correct ciphertexts
    let d = decryptor.decrypt_slots(&ctx, &enc, &parallel[0]);
    for i in 0..enc.slots() {
        assert!((d[i] - z[(i + 1) % enc.slots()]).abs() < 1e-5, "slot {i}");
    }
}

fn synth_forest(k: usize, l: usize, c: usize, d: usize, rng: &mut Xoshiro256pp) -> NeuralForest {
    let trees = (0..l)
        .map(|_| NeuralTree {
            tau: (0..k - 1).map(|_| rng.next_index(d)).collect(),
            t: (0..k - 1).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            v: (0..k)
                .map(|_| (0..k - 1).map(|_| rng.uniform(-0.25, 0.25)).collect())
                .collect(),
            b: (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            w: (0..c)
                .map(|_| (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect())
                .collect(),
            beta: (0..c).map(|_| rng.uniform(-0.2, 0.2)).collect(),
            real_leaves: k,
            n_classes: c,
        })
        .collect();
    NeuralForest {
        trees,
        alphas: (0..l).map(|_| rng.uniform(0.1, 1.0)).collect(),
        k,
        n_classes: c,
        activation: Activation::Poly {
            coeffs: vec![0.0, 1.0], // identity: fits the depth-4 ring
        },
    }
}

#[test]
fn hrf_execute_is_worker_count_invariant() {
    let mut rng = Xoshiro256pp::new(507);
    let d = 8;
    let nf = synth_forest(4, 3, 2, d, &mut rng);
    let params = Arc::new(CkksParams::build("kern-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;
    let b = plan.groups.min(3);

    let mut kg = KeyGenerator::new(&ctx, 508);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(b));
    let mut client = HrfClient::new(Encryptor::new(pk, 509), Decryptor::new(kg.secret_key()));
    let server = HrfServer::new(hm);

    let cts: Vec<Ciphertext> = (0..b)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(0.0, 1.0)).collect();
            client.encrypt_input(&ctx, &enc, &server.model, &x)
        })
        .collect();

    let run = |workers: usize| {
        ctx.set_workers(workers);
        let mut ev = Evaluator::new(ctx.clone());
        let ex = server.execute(&mut ev, &enc, &EncRequest::group(&cts), &rlk, &gk);
        (ex.counts, ex.into_class_scores())
    };
    let (counts_1, outs_1) = run(1);
    let (counts_4, outs_4) = run(4);
    ctx.set_workers(1);
    assert_eq!(counts_1, counts_4, "op accounting must not depend on workers");
    assert_eq!(outs_1.len(), plan.c);
    for (ci, (a, b)) in outs_1.iter().zip(&outs_4).enumerate() {
        assert!(
            ct_bits_equal(a, b),
            "class {ci}: execute at 4 workers deviates from serial bits"
        );
    }
}
