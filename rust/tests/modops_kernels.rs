//! Kernel parity for the RNS data plane (ISSUE 5):
//!
//! * The division-free Barrett/Shoup kernels must equal the
//!   `mul_mod` u128-division **oracle** on random and structured
//!   inputs, across **every** prime (chain + special) of the toy,
//!   fast and paper (`hrf_default`) parameter sets. CI runs this file
//!   under `--release` as well — the optimized kernels are the ones
//!   serving traffic, and debug-mode u128 paths can mask codegen
//!   regressions.
//! * Thread-count invariance: the limb-parallel executor must be a
//!   pure throughput knob — primitive op chains and full
//!   `HrfServer::execute` runs at worker counts 1 vs 4 produce
//!   **bit-identical** ciphertexts (`engine_parity`-style assertions).

use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::kernels;
use cryptotree::ckks::modops::{
    add_mod, barrett_precompute, barrett_reduce_128, barrett_reduce_64, mul_mod, mul_mod_barrett,
    mul_mod_barrett_lazy, mul_mod_shoup, shoup_precompute, sub_mod,
};
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{Ciphertext, CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::{Activation, NeuralForest, NeuralTree};
use cryptotree::rng::Xoshiro256pp;
use std::sync::Arc;

/// Every prime of every shipped parameter set (chain + special) —
/// `hrf_default` is the paper configuration.
fn parameter_set_primes() -> Vec<(&'static str, Vec<u64>)> {
    [CkksParams::toy(), CkksParams::fast(), CkksParams::hrf_default()]
        .into_iter()
        .map(|p| {
            let mut primes = p.moduli.clone();
            primes.push(p.special);
            (p.name, primes)
        })
        .collect()
}

/// Structured edge inputs around multiples of q and the u64 extremes.
fn edge_inputs(q: u64) -> Vec<u64> {
    let mut v = vec![0u64, 1, 2, q - 1, q, q + 1, u64::MAX, u64::MAX - 1, 1 << 63];
    // largest multiple of q that fits in u64, ±1
    let k = q * (u64::MAX / q);
    v.push(k);
    v.push(k - 1);
    v.push(k + 1);
    v
}

#[test]
fn barrett_mul_matches_oracle_on_all_parameter_set_primes() {
    let mut rng = Xoshiro256pp::new(500);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            let ratio = barrett_precompute(q);
            for _ in 0..2_000 {
                let (x, y) = (rng.next_below(q), rng.next_below(q));
                assert_eq!(
                    mul_mod_barrett(x, y, q, ratio),
                    mul_mod(x, y, q),
                    "{name} q={q} x={x} y={y}"
                );
            }
            // Unreduced operands (the kernel contract allows any u64).
            for _ in 0..500 {
                let (x, y) = (rng.next_u64(), rng.next_u64());
                assert_eq!(
                    mul_mod_barrett(x, y, q, ratio),
                    mul_mod(x, y, q),
                    "{name} q={q} unreduced x={x} y={y}"
                );
            }
            for &x in &edge_inputs(q) {
                for &y in &[0u64, 1, q - 1, u64::MAX] {
                    assert_eq!(mul_mod_barrett(x, y, q, ratio), mul_mod(x, y, q));
                }
            }
        }
    }
}

#[test]
fn barrett_reduce_64_matches_mod_on_all_parameter_set_primes() {
    let mut rng = Xoshiro256pp::new(501);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            let (_, r_hi) = barrett_precompute(q);
            for _ in 0..4_000 {
                let x = rng.next_u64();
                assert_eq!(barrett_reduce_64(x, q, r_hi), x % q, "{name} q={q} x={x}");
            }
            for &x in &edge_inputs(q) {
                assert_eq!(barrett_reduce_64(x, q, r_hi), x % q, "{name} q={q} edge {x}");
            }
        }
    }
}

#[test]
fn barrett_reduce_128_matches_mod_on_all_parameter_set_primes() {
    let mut rng = Xoshiro256pp::new(502);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            let ratio = barrett_precompute(q);
            let oracle =
                |lo: u64, hi: u64| ((((hi as u128) << 64) | lo as u128) % q as u128) as u64;
            for _ in 0..2_000 {
                let (lo, hi) = (rng.next_u64(), rng.next_u64());
                assert_eq!(
                    barrett_reduce_128(lo, hi, q, ratio),
                    oracle(lo, hi),
                    "{name} q={q} lo={lo} hi={hi}"
                );
            }
            for &(lo, hi) in &[
                (0u64, 0u64),
                (q - 1, 0),
                (u64::MAX, u64::MAX),
                (0, u64::MAX),
                (u64::MAX, 0),
            ] {
                assert_eq!(barrett_reduce_128(lo, hi, q, ratio), oracle(lo, hi));
            }
            // products of near-maximal residues (the dyadic-mul shape)
            for _ in 0..500 {
                let (a, b) = (q - 1 - rng.next_below(4), q - 1 - rng.next_below(4));
                let p = a as u128 * b as u128;
                assert_eq!(
                    barrett_reduce_128(p as u64, (p >> 64) as u64, q, ratio),
                    (p % q as u128) as u64
                );
            }
        }
    }
}

#[test]
fn shoup_mul_matches_oracle_for_arbitrary_left_operand() {
    // Shoup multiplication requires only y < q; the left operand may
    // be any u64 (the lazy NTT and the CRT digit path rely on this).
    let mut rng = Xoshiro256pp::new(503);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            for _ in 0..2_000 {
                let y = rng.next_below(q);
                let ys = shoup_precompute(y, q);
                let x = rng.next_u64();
                assert_eq!(
                    mul_mod_shoup(x, y, ys, q),
                    mul_mod(x % q, y, q),
                    "{name} q={q} x={x} y={y}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Lazy-reduction batch kernels (ISSUE 10) vs the oracle
// ---------------------------------------------------------------------

/// Slice length that exercises both the 8-wide blocks and the scalar
/// tail of every batch kernel.
const KLEN: usize = 4 * kernels::LANES + 3;

fn rand_slice(rng: &mut Xoshiro256pp, bound: u64, len: usize) -> Vec<u64> {
    (0..len).map(|_| rng.next_below(bound)).collect()
}

#[test]
fn lazy_barrett_mul_is_congruent_and_in_domain() {
    let mut rng = Xoshiro256pp::new(510);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            let ratio = barrett_precompute(q);
            for _ in 0..2_000 {
                let (x, y) = (rng.next_below(q), rng.next_below(q));
                let lazy = mul_mod_barrett_lazy(x, y, q, ratio);
                assert!(lazy < 2 * q, "{name} q={q}: lazy result out of [0,2q)");
                let reduced = if lazy >= q { lazy - q } else { lazy };
                assert_eq!(reduced, mul_mod(x, y, q), "{name} q={q} x={x} y={y}");
            }
        }
    }
}

#[test]
fn batch_kernels_match_scalar_oracle_on_all_parameter_set_primes() {
    let mut rng = Xoshiro256pp::new(511);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            let ratio = barrett_precompute(q);
            let a0 = rand_slice(&mut rng, q, KLEN);
            let b0 = rand_slice(&mut rng, q, KLEN);

            let mut add = a0.clone();
            kernels::add_mod_slice(&mut add, &b0, q);
            let mut sub = a0.clone();
            kernels::sub_mod_slice(&mut sub, &b0, q);
            let mut mul = a0.clone();
            kernels::mul_mod_slice(&mut mul, &b0, q, ratio);
            let mut mul_lazy = a0.clone();
            kernels::mul_mod_slice_lazy(&mut mul_lazy, &b0, q, ratio);
            for i in 0..KLEN {
                assert_eq!(add[i], add_mod(a0[i], b0[i], q), "{name} q={q} add i={i}");
                assert_eq!(sub[i], sub_mod(a0[i], b0[i], q), "{name} q={q} sub i={i}");
                assert_eq!(mul[i], mul_mod(a0[i], b0[i], q), "{name} q={q} mul i={i}");
                assert!(mul_lazy[i] < 2 * q, "{name} q={q} lazy domain i={i}");
                let red = if mul_lazy[i] >= q {
                    mul_lazy[i] - q
                } else {
                    mul_lazy[i]
                };
                assert_eq!(red, mul[i], "{name} q={q} lazy congruence i={i}");
            }

            // Fused tensor + square kernels.
            let a1 = rand_slice(&mut rng, q, KLEN);
            let b1 = rand_slice(&mut rng, q, KLEN);
            let (mut d0, mut d1, mut d2) = (vec![0; KLEN], vec![0; KLEN], vec![0; KLEN]);
            kernels::tensor_limb(&a0, &a1, &b0, &b1, &mut d0, &mut d1, &mut d2, q, ratio);
            for i in 0..KLEN {
                assert_eq!(d0[i], mul_mod(a0[i], b0[i], q), "{name} tensor d0 i={i}");
                let cross = add_mod(mul_mod(a0[i], b1[i], q), mul_mod(a1[i], b0[i], q), q);
                assert_eq!(d1[i], cross, "{name} tensor d1 i={i}");
                assert_eq!(d2[i], mul_mod(a1[i], b1[i], q), "{name} tensor d2 i={i}");
            }
            kernels::square_limb(&a0, &a1, &mut d0, &mut d1, &mut d2, q, ratio);
            for i in 0..KLEN {
                assert_eq!(d0[i], mul_mod(a0[i], a0[i], q), "{name} square d0 i={i}");
                let c = mul_mod(a0[i], a1[i], q);
                assert_eq!(d1[i], add_mod(c, c, q), "{name} square d1 i={i}");
                assert_eq!(d2[i], mul_mod(a1[i], a1[i], q), "{name} square d2 i={i}");
            }
        }
    }
}

#[test]
fn rescale_adjust_kernels_match_scalar_path() {
    let mut rng = Xoshiro256pp::new(512);
    for (name, primes) in parameter_set_primes() {
        // Last prime plays the dropped modulus against every other.
        let q_last = *primes.last().unwrap();
        let half = q_last / 2;
        for &q in primes.iter().filter(|&&p| p != q_last) {
            let (_, r_hi) = barrett_precompute(q);
            let inv = 1 + rng.next_below(q - 1);
            let inv_sh = shoup_precompute(inv, q);
            let limb0 = rand_slice(&mut rng, q, KLEN);
            let last = rand_slice(&mut rng, q_last, KLEN);

            let mut limb = limb0.clone();
            kernels::rescale_adjust_slice(&mut limb, &last, q, r_hi, q_last, half, inv, inv_sh);
            for i in 0..KLEN {
                let r = last[i];
                let adjusted = if r <= half {
                    sub_mod(limb0[i], r % q, q)
                } else {
                    add_mod(limb0[i], (q_last - r) % q, q)
                };
                assert_eq!(
                    limb[i],
                    mul_mod(adjusted, inv, q),
                    "{name} q={q} rescale i={i}"
                );
            }

            let mut dst = vec![0u64; KLEN];
            kernels::centered_neg_slice(&mut dst, &last, q_last, half, q, r_hi);
            for i in 0..KLEN {
                let r = last[i];
                let want = if r <= half {
                    let red = r % q;
                    if red == 0 {
                        0
                    } else {
                        q - red
                    }
                } else {
                    (q_last - r) % q
                };
                assert_eq!(dst[i], want, "{name} q={q} centered_neg i={i}");
            }

            let mut acc = limb0.clone();
            let addend = rand_slice(&mut rng, q, KLEN);
            kernels::add_then_mul_shoup_slice(&mut acc, &addend, q, inv, inv_sh);
            for i in 0..KLEN {
                let want = mul_mod(add_mod(limb0[i], addend[i], q), inv, q);
                assert_eq!(acc[i], want, "{name} q={q} add_then_mul i={i}");
            }
        }
    }
}

#[test]
fn mac_accumulator_survives_full_headroom_with_lazy_inputs() {
    // Adversarial near-overflow: the largest prime of every parameter
    // set, the maximum admissible digit count D = mac_headroom(q), and
    // every operand at the lazy-domain maximum 2q−1. D·(2q−1)² is the
    // largest sum the accumulator contract admits; one more term would
    // overflow u128 (pinned in the kernels unit tests).
    for (name, primes) in parameter_set_primes() {
        let q = *primes.iter().max().unwrap();
        let ratio = barrett_precompute(q);
        let d_max = kernels::mac_headroom(q);
        assert!(d_max >= 10, "{name}: headroom too small for the chain");
        let n = 2 * kernels::LANES + 1;
        let x = vec![2 * q - 1; n];
        let mut lo = vec![0u64; n];
        let mut hi = vec![0u64; n];
        for _ in 0..d_max {
            kernels::mac_acc_slice(&mut lo, &mut hi, &x, &x, 2 * q);
        }
        let mut out = vec![0u64; n];
        kernels::reduce_acc_slice(&mut out, &lo, &hi, q, ratio);
        // Oracle: D·(2q−1)² mod q, one fully-reduced term at a time.
        let term = mul_mod((2 * q - 1) % q, (2 * q - 1) % q, q);
        let mut want = 0u64;
        for _ in 0..d_max {
            want = add_mod(want, term, q);
        }
        assert!(out.iter().all(|&v| v == want), "{name} q={q}");
    }
}

#[test]
fn mac_kernels_match_oracle_with_random_lazy_inputs() {
    let mut rng = Xoshiro256pp::new(513);
    for (name, primes) in parameter_set_primes() {
        for q in primes {
            let ratio = barrett_precompute(q);
            let digits = 10usize.min(kernels::mac_headroom(q).saturating_sub(1));
            let xs: Vec<Vec<u64>> = (0..digits)
                .map(|_| rand_slice(&mut rng, 2 * q, KLEN))
                .collect();
            let ks: Vec<Vec<u64>> = (0..digits)
                .map(|_| rand_slice(&mut rng, 2 * q, KLEN))
                .collect();
            let init = rand_slice(&mut rng, q, KLEN);
            let mut lo = init.clone();
            let mut hi = vec![0u64; KLEN];
            for (x, k) in xs.iter().zip(ks.iter()) {
                kernels::mac_acc_slice(&mut lo, &mut hi, x, k, 2 * q);
            }
            let mut out = vec![0u64; KLEN];
            kernels::reduce_acc_slice(&mut out, &lo, &hi, q, ratio);
            for i in 0..KLEN {
                let mut want = init[i];
                for (x, k) in xs.iter().zip(ks.iter()) {
                    want = add_mod(want, mul_mod(x[i] % q, k[i] % q, q), q);
                }
                assert_eq!(out[i], want, "{name} q={q} i={i}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Thread-count invariance
// ---------------------------------------------------------------------

fn ct_bits_equal(a: &Ciphertext, b: &Ciphertext) -> bool {
    a.level == b.level
        && a.scale.to_bits() == b.scale.to_bits()
        && a.c0.data() == b.c0.data()
        && a.c1.data() == b.c1.data()
}

#[test]
fn primitive_chain_is_worker_count_invariant() {
    let ctx = CkksContext::new(CkksParams::toy());
    let enc = Encoder::new(&ctx);
    let mut kg = KeyGenerator::new(&ctx, 504);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &[1, 2, 4]);
    let mut encryptor = Encryptor::new(pk, 505);
    let decryptor = Decryptor::new(kg.secret_key());
    let mut rng = Xoshiro256pp::new(506);
    let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let ct = encryptor.encrypt_slots(&ctx, &enc, &z);

    let pt = enc.encode(&ctx, &z, ct.level, ctx.params.scale);
    let run = |workers: usize| -> Vec<Ciphertext> {
        ctx.set_workers(workers);
        let mut ev = Evaluator::new(ctx.clone());
        let rot = ev.rotate(&ct, 1, &gk);
        let digits = ev.hoist(&ct);
        let hrot = ev.rotate_hoisted(&ct, &digits, 2, &gk);
        let mut prod = ev.mul(&ct, &rot, &rlk);
        ev.rescale(&mut prod);
        let mut sq = ev.square(&ct, &rlk);
        ev.rescale(&mut sq);
        let sum = ev.rotate_sum(&sq, 4, &gk);
        // the lazy-fused kernel path (mul_assign_lazy + rescale)
        let fused = ev.mul_plain_rescale(&ct, &pt);
        vec![rot, hrot, prod, sq, sum, fused]
    };
    let serial = run(1);
    let parallel = run(4);
    ctx.set_workers(1);
    assert_eq!(serial.len(), parallel.len());
    for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
        assert!(ct_bits_equal(a, b), "primitive chain output {i} differs");
    }
    // and the results are still correct ciphertexts
    let d = decryptor.decrypt_slots(&ctx, &enc, &parallel[0]);
    for i in 0..enc.slots() {
        assert!((d[i] - z[(i + 1) % enc.slots()]).abs() < 1e-5, "slot {i}");
    }
}

#[test]
fn fused_mul_plain_rescale_is_bit_identical_to_unfused() {
    // The FuseMulRescale execution target now runs the ring multiplies
    // lazily ([0, 2q)) into the rescale's inverse NTT; the separate
    // mul_plain + rescale path reduces fully at each step. Outputs must
    // be bit-identical at 1 and 4 workers.
    let ctx = CkksContext::new(CkksParams::toy());
    let enc = Encoder::new(&ctx);
    let mut kg = KeyGenerator::new(&ctx, 514);
    let pk = kg.gen_public_key(&ctx);
    let mut encryptor = Encryptor::new(pk, 515);
    let mut rng = Xoshiro256pp::new(516);
    let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let w: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let ct = encryptor.encrypt_slots(&ctx, &enc, &z);
    let pt = enc.encode(&ctx, &w, ct.level, ctx.params.scale);
    for workers in [1usize, 4] {
        ctx.set_workers(workers);
        let mut ev = Evaluator::new(ctx.clone());
        let mut unfused = ev.mul_plain(&ct, &pt);
        ev.rescale(&mut unfused);
        let fused = ev.mul_plain_rescale(&ct, &pt);
        assert!(
            ct_bits_equal(&unfused, &fused),
            "fused path deviates at workers={workers}"
        );
    }
    ctx.set_workers(1);
}

/// Acceptance pin for the lazy MAC: the key-switch inner product
/// performs exactly **one** Barrett reduction per (coefficient, limb),
/// independent of the digit count. Debug builds count reductions in a
/// thread-local; with `ckks_workers = 1` every limb runs on this
/// thread, so the delta per rotation must be exactly
/// `2 polys × n × (level + 2) limbs` — a formula with no digit factor,
/// even though the digit count changes with the level.
#[cfg(debug_assertions)]
#[test]
fn keyswitch_performs_one_reduction_per_coefficient_limb() {
    use cryptotree::ckks::kernels::counters;
    let ctx = CkksContext::new(CkksParams::toy());
    let enc = Encoder::new(&ctx);
    let mut kg = KeyGenerator::new(&ctx, 517);
    let pk = kg.gen_public_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &[1]);
    let mut encryptor = Encryptor::new(pk, 518);
    let mut rng = Xoshiro256pp::new(519);
    let z: Vec<f64> = (0..enc.slots()).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let mut ct = encryptor.encrypt_slots(&ctx, &enc, &z);
    ctx.set_workers(1);
    let mut ev = Evaluator::new(ctx.clone());
    let n = ctx.n() as u64;
    loop {
        let digits = ct.level + 1; // decompose emits level+1 digits
        let before = counters::mac_reductions();
        let _ = ev.rotate(&ct, 1, &gk);
        let delta = counters::mac_reductions() - before;
        assert_eq!(
            delta,
            2 * n * (ct.level as u64 + 2),
            "level={} digits={digits}: reductions must not scale with digits",
            ct.level
        );
        if ct.level == 0 {
            break;
        }
        ct.c0.drop_to_level(ct.level - 1);
        ct.c1.drop_to_level(ct.level - 1);
        ct.level -= 1;
    }
}

fn synth_forest(k: usize, l: usize, c: usize, d: usize, rng: &mut Xoshiro256pp) -> NeuralForest {
    let trees = (0..l)
        .map(|_| NeuralTree {
            tau: (0..k - 1).map(|_| rng.next_index(d)).collect(),
            t: (0..k - 1).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            v: (0..k)
                .map(|_| (0..k - 1).map(|_| rng.uniform(-0.25, 0.25)).collect())
                .collect(),
            b: (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            w: (0..c)
                .map(|_| (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect())
                .collect(),
            beta: (0..c).map(|_| rng.uniform(-0.2, 0.2)).collect(),
            real_leaves: k,
            n_classes: c,
        })
        .collect();
    NeuralForest {
        trees,
        alphas: (0..l).map(|_| rng.uniform(0.1, 1.0)).collect(),
        k,
        n_classes: c,
        activation: Activation::Poly {
            coeffs: vec![0.0, 1.0], // identity: fits the depth-4 ring
        },
    }
}

#[test]
fn hrf_execute_is_worker_count_invariant() {
    let mut rng = Xoshiro256pp::new(507);
    let d = 8;
    let nf = synth_forest(4, 3, 2, d, &mut rng);
    let params = Arc::new(CkksParams::build("kern-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;
    let b = plan.groups.min(3);

    let mut kg = KeyGenerator::new(&ctx, 508);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(b));
    let mut client = HrfClient::new(Encryptor::new(pk, 509), Decryptor::new(kg.secret_key()));
    let server = HrfServer::new(hm);

    let cts: Vec<Ciphertext> = (0..b)
        .map(|_| {
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(0.0, 1.0)).collect();
            client.encrypt_input(&ctx, &enc, &server.model, &x)
        })
        .collect();

    let run = |workers: usize| {
        ctx.set_workers(workers);
        let mut ev = Evaluator::new(ctx.clone());
        let ex = server.execute(&mut ev, &enc, &EncRequest::group(&cts), &rlk, &gk);
        (ex.counts, ex.into_class_scores())
    };
    let (counts_1, outs_1) = run(1);
    let (counts_4, outs_4) = run(4);
    ctx.set_workers(1);
    assert_eq!(counts_1, counts_4, "op accounting must not depend on workers");
    assert_eq!(outs_1.len(), plan.c);
    for (ci, (a, b)) in outs_1.iter().zip(&outs_4).enumerate() {
        assert!(
            ct_bits_equal(a, b),
            "class {ci}: execute at 4 workers deviates from serial bits"
        );
    }
}
