//! PJRT runtime integration: load the AOT artifacts produced by
//! `make artifacts` and cross-check the JAX/Pallas slot model against
//! the Rust implementations — the three-layer consistency proof:
//!
//!   rust HE (CKKS)  ≈  rust slot math  ==  AOT JAX/Pallas via PJRT
//!
//! Tests are skipped (with a loud message) when artifacts are absent.

use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::reshuffle_and_pack;
use cryptotree::hrf::HrfModel;
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;
use cryptotree::runtime::{SlotModel, SlotModelParams};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        None
    }
}

/// Build an HRF packed to exactly the artifact's shape (S=4096, K=16).
fn model_for_artifact() -> (cryptotree::data::Dataset, NeuralForest, HrfModel) {
    let ds = adult::generate(2_000, 515);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 24, // 24 * 31 = 744 <= 4096 slots
            ..Default::default()
        },
        516,
    );
    let coeffs = chebyshev_fit_tanh(3.0, 4);
    let nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
    assert_eq!(nf.k, 16, "tree depth 4 must pad to K=16");
    let hm = HrfModel::from_neural_forest(&nf, ds.n_features(), 4096).unwrap();
    (ds, nf, hm)
}

#[test]
fn pjrt_single_matches_rust_slot_math() {
    let Some(dir) = artifacts_dir() else { return };
    let (ds, nf, hm) = model_for_artifact();
    let sm = SlotModel::load(&dir).expect("load artifacts");
    let params = SlotModelParams::from_hrf(&hm, sm.shape).expect("pack params");
    for x in ds.x.iter().take(32) {
        let slots = reshuffle_and_pack(&hm, x);
        let slots_f32: Vec<f32> = slots.iter().map(|&v| v as f32).collect();
        let got = sm.infer(&slots_f32, &params).expect("pjrt infer");
        let want = hm.forward_slots_plain(&slots);
        let want_nrf = nf.forward(x);
        for c in 0..want.len() {
            assert!(
                (got[c] as f64 - want[c]).abs() < 1e-3,
                "PJRT vs rust slot math: {got:?} vs {want:?}"
            );
            assert!(
                (got[c] as f64 - want_nrf[c]).abs() < 1e-3,
                "PJRT vs NRF forward: {got:?} vs {want_nrf:?}"
            );
        }
    }
}

#[test]
fn pjrt_batch_matches_single() {
    let Some(dir) = artifacts_dir() else { return };
    let (ds, _nf, hm) = model_for_artifact();
    let sm = SlotModel::load(&dir).expect("load artifacts");
    let params = SlotModelParams::from_hrf(&hm, sm.shape).expect("pack params");
    let xs: Vec<Vec<f32>> = ds
        .x
        .iter()
        .take(5) // deliberately partial batch (B=8)
        .map(|x| {
            reshuffle_and_pack(&hm, x)
                .iter()
                .map(|&v| v as f32)
                .collect()
        })
        .collect();
    let batch = sm.infer_batch(&xs, &params).expect("batch infer");
    assert_eq!(batch.len(), 5);
    for (i, x) in xs.iter().enumerate() {
        let single = sm.infer(x, &params).expect("single infer");
        for c in 0..single.len() {
            assert!(
                (batch[i][c] - single[c]).abs() < 1e-5,
                "batch/single divergence at sample {i}"
            );
        }
    }
}

#[test]
fn coordinator_uses_pjrt_fast_path() {
    let Some(dir) = artifacts_dir() else { return };
    use cryptotree::ckks::rns::CkksContext;
    use cryptotree::ckks::CkksParams;
    use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager};
    use cryptotree::hrf::HrfServer;
    use std::sync::Arc;

    let (ds, _nf, hm) = model_for_artifact();
    // fast params: N=8192 → 4096 slots == artifact S.
    let ctx = CkksContext::new(CkksParams::fast());
    let server = Arc::new(HrfServer::new(hm));
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch: 8,
            ..Default::default()
        },
        ctx,
        server.clone(),
        Arc::new(SessionManager::new()),
        Some(dir),
    );
    let rxs: Vec<_> = (0..6)
        .map(|i| coord.submit_plain(ds.x[i].clone()).expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let scores = rx.recv().unwrap().expect("pjrt plain path");
        let slots = reshuffle_and_pack(&server.model, &ds.x[i]);
        let want = server.model.forward_slots_plain(&slots);
        for (g, e) in scores.iter().zip(&want) {
            assert!(
                (g - e).abs() < 1e-3,
                "coordinator PJRT path deviates: {scores:?} vs {want:?}"
            );
        }
    }
    assert_eq!(coord.metrics.snapshot().plain_completed, 6);
    coord.shutdown();
}
