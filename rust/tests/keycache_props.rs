//! Property tests for the keycache subsystem. Hand-rolled generators
//! (the proptest crate is unavailable offline — same idiom as the
//! coordinator/batcher property tests): a reference model replays
//! every operation and the cache must agree exactly.
//!
//! Properties:
//! 1. resident bytes never exceed the budget (entry sizes ≤ budget);
//! 2. LRU order is respected — the eviction victim is always the
//!    least-recently-used entry (per shard and globally, since ticks
//!    are global);
//! 3. evicted sessions recover via re-registration under the same id,
//!    with bit-identical inference results (end-to-end HE test).

use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::coordinator::{
    CacheState, Coordinator, CoordinatorConfig, SessionManager, SubmitError,
};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::{reshuffle_and_pack, HrfClient};
use cryptotree::hrf::{HrfModel, HrfServer};
use cryptotree::keycache::{KeyCache, KeyCacheConfig};
use cryptotree::nrf::activation::Activation;
use cryptotree::nrf::NeuralForest;
use cryptotree::rng::Xoshiro256pp;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// Reference model: the cache's exact single-threaded semantics.
/// `order` is the global LRU list (front = oldest); eviction removes
/// the front entry, skipping the id being kept (the fresh insert).
struct Model {
    budget: u64,
    order: Vec<u64>,
    bytes: HashMap<u64, u64>,
    known: std::collections::HashSet<u64>,
    resident: u64,
}

impl Model {
    fn new(budget: u64) -> Self {
        Model {
            budget,
            order: Vec::new(),
            bytes: HashMap::new(),
            known: std::collections::HashSet::new(),
            resident: 0,
        }
    }

    fn touch(&mut self, id: u64) {
        if let Some(pos) = self.order.iter().position(|&x| x == id) {
            self.order.remove(pos);
            self.order.push(id);
        }
    }

    fn insert(&mut self, id: u64, b: u64) {
        if let Some(old) = self.bytes.get(&id).copied() {
            if self.order.contains(&id) {
                self.resident -= old;
            }
        }
        if let Some(pos) = self.order.iter().position(|&x| x == id) {
            self.order.remove(pos);
        }
        self.order.push(id);
        self.bytes.insert(id, b);
        self.known.insert(id);
        self.resident += b;
        while self.resident > self.budget {
            let victim = match self.order.iter().position(|&x| x != id) {
                Some(p) => self.order.remove(p),
                None => break, // only the kept entry left
            };
            self.resident -= self.bytes[&victim];
        }
    }

    fn get(&mut self, id: u64) -> &'static str {
        if self.order.contains(&id) {
            self.touch(id);
            "resident"
        } else if self.known.contains(&id) {
            "evicted"
        } else {
            "unknown"
        }
    }

    fn remove(&mut self, id: u64) -> bool {
        if let Some(pos) = self.order.iter().position(|&x| x == id) {
            self.order.remove(pos);
            self.resident -= self.bytes[&id];
        }
        self.bytes.remove(&id);
        self.known.remove(&id)
    }

    fn state(&self, id: u64) -> &'static str {
        if self.order.contains(&id) {
            "resident"
        } else if self.known.contains(&id) {
            "evicted"
        } else {
            "unknown"
        }
    }
}

fn state_of(c: &KeyCache<u64>, id: u64) -> &'static str {
    match c.peek(id) {
        CacheState::Resident(_) => "resident",
        CacheState::Evicted => "evicted",
        // No spill tier is enabled in these tests, so this state is
        // unreachable here (spill semantics live in mem_props.rs).
        CacheState::Spilled => "spilled",
        CacheState::Unknown => "unknown",
    }
}

/// Property 1 + 2: under random insert/get/remove sequences the cache
/// matches the exact-LRU reference model and never exceeds the budget.
#[test]
fn property_cache_matches_lru_model_and_budget() {
    let mut rng = Xoshiro256pp::new(2024);
    for case in 0..60 {
        let shards = 1 + rng.next_index(5);
        let budget = 200 + rng.next_below(1_800);
        let cache: KeyCache<u64> = KeyCache::new(KeyCacheConfig {
            num_shards: shards,
            budget_bytes: budget,
        });
        let mut model = Model::new(budget);
        let id_space = 24u64;
        for step in 0..300 {
            let roll = rng.next_f64();
            if roll < 0.55 {
                let id = rng.next_below(id_space);
                // Entry sizes stay within the budget so the invariant
                // is exact (oversized entries are a documented
                // exception, tested separately).
                let b = 1 + rng.next_below(budget.min(500));
                cache.insert(id, id, b as usize);
                model.insert(id, b);
            } else if roll < 0.85 {
                let id = rng.next_below(id_space + 4); // sometimes unknown
                let got = match cache.lookup(id) {
                    CacheState::Resident(_) => "resident",
                    CacheState::Evicted => "evicted",
                    CacheState::Spilled => "spilled", // unreachable: no spill tier
                    CacheState::Unknown => "unknown",
                };
                let want = model.get(id);
                assert_eq!(got, want, "case {case} step {step}: lookup({id})");
            } else {
                let id = rng.next_below(id_space + 4);
                assert_eq!(
                    cache.remove(id),
                    model.remove(id),
                    "case {case} step {step}: remove({id})"
                );
            }
            // Invariants after every operation.
            assert!(
                cache.resident_bytes() <= budget,
                "case {case} step {step}: resident {} > budget {budget}",
                cache.resident_bytes()
            );
            assert_eq!(
                cache.resident_bytes(),
                model.resident,
                "case {case} step {step}: gauge drifted from model"
            );
            assert_eq!(cache.resident_len(), model.order.len());
        }
        // Full-state agreement at the end of the case.
        for id in 0..id_space + 4 {
            assert_eq!(
                state_of(&cache, id),
                model.state(id),
                "case {case}: final state of {id}"
            );
        }
    }
}

/// Explicit single-shard LRU check (readable counterpart to the model
/// test): the victim is always the least-recently-*used*, not the
/// least-recently-inserted.
#[test]
fn lru_victim_is_least_recently_used() {
    let cache: KeyCache<u64> = KeyCache::new(KeyCacheConfig {
        num_shards: 1,
        budget_bytes: 3,
    });
    cache.insert(0, 0, 1);
    cache.insert(1, 1, 1);
    cache.insert(2, 2, 1);
    assert!(cache.get(0).is_some()); // 0 is now hottest
    cache.insert(3, 3, 1); // must evict 1
    assert!(matches!(cache.peek(1), CacheState::Evicted));
    for id in [0u64, 2, 3] {
        assert!(
            matches!(cache.peek(id), CacheState::Resident(_)),
            "id {id} should have survived"
        );
    }
}

/// Property 3 (end-to-end): with a budget admitting one session, a
/// second registration evicts the first; the first session fails fast
/// with KeysEvicted, re-registers under the same id, and then produces
/// scores identical to its pre-eviction evaluation.
#[test]
fn evicted_session_recovers_with_identical_results() {
    // Cheap ring (N=4096, depth 4) + identity activation: the protocol
    // is under test, not the numerics.
    let mut rng = Xoshiro256pp::new(4242);
    let params = Arc::new(CkksParams::build("keycache-e2e-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let ds = adult::generate(400, 515);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 4,
            tree: cryptotree::forest::tree::TreeConfig {
                max_depth: 2,
                ..Default::default()
            },
            ..Default::default()
        },
        516,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: vec![0.0, 1.0],
        },
    );
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
    let server = Arc::new(HrfServer::new(model));

    // Client A retains its keys; client B only exists to apply cache
    // pressure.
    let mut kg_a = KeyGenerator::new(&ctx, 517);
    let pk_a = kg_a.gen_public_key(&ctx);
    let rlk_a = kg_a.gen_relin_key(&ctx);
    let gk_a = kg_a.gen_galois_keys(&ctx, &server.eval_key_requirements(1));
    let session_bytes = (rlk_a.key_bytes() + gk_a.key_bytes()) as u64;
    let mut client_a = HrfClient::with_eval_keys(
        Encryptor::new(pk_a, 518),
        Decryptor::new(kg_a.secret_key()),
        rlk_a,
        gk_a,
    );
    let mut kg_b = KeyGenerator::new(&ctx, 519);
    let _pk_b = kg_b.gen_public_key(&ctx);
    let rlk_b = kg_b.gen_relin_key(&ctx);
    let gk_b = kg_b.gen_galois_keys(&ctx, &server.eval_key_requirements(1));

    // Budget fits one session (plus slack), not two.
    let sessions = Arc::new(SessionManager::with_config(KeyCacheConfig {
        num_shards: 4,
        budget_bytes: session_bytes * 3 / 2,
    }));
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            queue_capacity: 16,
            ..Default::default()
        },
        ctx.clone(),
        server.clone(),
        sessions.clone(),
        None,
    );

    let sid_a = sessions.register_keys(client_a.eval_keys().expect("retained keys"));
    let x: Vec<f64> = (0..server.model.plan.d)
        .map(|_| rng.next_f64() * 2.0 - 1.0)
        .collect();
    let ct = client_a.encrypt_input(&ctx, &enc, &server.model, &x);

    // Baseline evaluation before any eviction.
    let rx = coord.submit_encrypted(sid_a, ct.clone()).expect("submit");
    let outs = rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    let (scores_before, _) = client_a.decrypt_response(&ctx, &enc, &outs);

    // Pressure: registering B must evict A's keys (global budget).
    let _sid_b = sessions.register(rlk_b, gk_b);
    assert!(sessions.resident_bytes() <= session_bytes * 3 / 2);
    assert!(matches!(sessions.lookup(sid_a), CacheState::Evicted));

    // The protocol: fail fast → re-register (same id) → resubmit.
    match coord.submit_encrypted(sid_a, ct.clone()) {
        Err(SubmitError::KeysEvicted) => {}
        other => panic!("expected KeysEvicted, got {:?}", other.map(|_| ())),
    }
    assert!(sessions.reregister_keys(sid_a, client_a.eval_keys().unwrap()));
    let rx = coord
        .submit_encrypted(sid_a, ct.clone())
        .expect("submit after re-registration");
    let outs = rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    let (scores_after, _) = client_a.decrypt_response(&ctx, &enc, &outs);

    // Same ciphertext + same keys → bit-identical decrypted scores.
    assert_eq!(scores_before.len(), scores_after.len());
    for (b, a) in scores_before.iter().zip(&scores_after) {
        assert!(
            (b - a).abs() < 1e-9,
            "recovered session diverged: {scores_before:?} vs {scores_after:?}"
        );
    }
    // And both agree with the plaintext slot model.
    let expect = server
        .model
        .forward_slots_plain(&reshuffle_and_pack(&server.model, &x));
    for (s, e) in scores_after.iter().zip(&expect) {
        assert!((s - e).abs() < 5e-3, "HE vs plain: {scores_after:?} vs {expect:?}");
    }

    let snap = coord.metrics.snapshot();
    assert!(snap.rejected_keys_evicted >= 1);
    assert!(snap.keycache_evictions >= 1);
    assert!(snap.keycache_misses >= 1);
    assert!(snap.keycache_resident_bytes <= session_bytes * 3 / 2);
    coord.shutdown();
}

/// 4K sessions against a budget admitting ~K: the acceptance-criteria
/// shape. Resident bytes stay bounded, exactly K sessions stay
/// resident, and every registered id remains known (re-registerable).
#[test]
fn four_times_overcommit_stays_within_budget() {
    let per_session = 64u64; // synthetic key bytes
    let k = 32u64;
    let budget = k * per_session;
    let cache: KeyCache<u64> = KeyCache::new(KeyCacheConfig {
        num_shards: 8,
        budget_bytes: budget,
    });
    let n = 4 * k;
    for id in 0..n {
        cache.insert(id, id, per_session as usize);
        assert!(cache.resident_bytes() <= budget);
    }
    assert_eq!(cache.resident_bytes(), budget);
    assert_eq!(cache.resident_len(), k as usize);
    assert_eq!(cache.known_len(), n as usize);
    // The resident set is exactly the K most recent registrations.
    for id in 0..n {
        let want = if id >= n - k { "resident" } else { "evicted" };
        assert_eq!(state_of(&cache, id), want, "id {id}");
    }
    let stats = cache.stats().snapshot();
    assert_eq!(stats.evictions, n - k);
}

/// Duplicate-rotation requests produce canonical key sets, so cache
/// accounting is stable across how a client phrases its key request.
#[test]
fn duplicate_rotations_do_not_inflate_accounting() {
    let ctx = CkksContext::new(CkksParams::toy());
    let gk_a = KeyGenerator::new(&ctx, 7).gen_galois_keys(&ctx, &[1, 2, 1, 2, 0, 2]);
    let gk_b = KeyGenerator::new(&ctx, 7).gen_galois_keys(&ctx, &[2, 1]);
    assert_eq!(gk_a.supported_rotations(), vec![1, 2]);
    assert_eq!(gk_a.key_bytes(), gk_b.key_bytes());
}
