//! Properties of the schedule-DAG parallel executor (PR 8):
//!
//! * **Well-formedness** — on real compiled schedules (every batch
//!   size, folded and unfolded, every pass pipeline) the hazard DAG is
//!   acyclic with mutually-consistent edge lists, and an independent
//!   brute-force hazard oracle confirms every conflicting op pair is
//!   ordered by a DAG path (register last-use/WAR edges included).
//! * **Determinism** — `Engine::run_parallel` is *exactly* the serial
//!   interpreter: bit-identical f32 slot outputs at any worker count,
//!   and bit-identical ciphertexts from `HrfServer::execute` over the
//!   full `B × op_workers × ckks_workers × passes` grid.
//! * **Failure** — a panicking worker surfaces as a typed
//!   [`DagExecError::WorkerPanic`], never a hang.
//! * **ReuseRegisters** — the liveness pass shrinks the folded batch
//!   schedule's register file to its live peak without changing
//!   results.

use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{Ciphertext, CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::hrf::client::{reshuffle_and_pack, HrfClient};
use cryptotree::hrf::schedule::{HrfSchedule, ScheduleOp};
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::{Activation, NeuralForest, NeuralTree};
use cryptotree::rng::Xoshiro256pp;
use cryptotree::runtime::engine::{
    CostModel, DagExecError, Engine, PassPipeline, ReuseRegisters, ScheduleBackend, ScheduleDag,
    SchedulePass, SlotBackend,
};
use cryptotree::runtime::{SlotModelParams, SlotShape};
use std::sync::Arc;

fn synth_forest(k: usize, l: usize, c: usize, d: usize, rng: &mut Xoshiro256pp) -> NeuralForest {
    let trees = (0..l)
        .map(|_| NeuralTree {
            tau: (0..k - 1).map(|_| rng.next_index(d)).collect(),
            t: (0..k - 1).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            v: (0..k)
                .map(|_| (0..k - 1).map(|_| rng.uniform(-0.25, 0.25)).collect())
                .collect(),
            b: (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            w: (0..c)
                .map(|_| (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect())
                .collect(),
            beta: (0..c).map(|_| rng.uniform(-0.2, 0.2)).collect(),
            real_leaves: k,
            n_classes: c,
        })
        .collect();
    NeuralForest {
        trees,
        alphas: (0..l).map(|_| rng.uniform(0.1, 1.0)).collect(),
        k,
        n_classes: c,
        activation: Activation::Poly {
            coeffs: vec![0.0, 1.0], // identity: fits the depth-4 ring
        },
    }
}

fn ct_bits_equal(a: &Ciphertext, b: &Ciphertext) -> bool {
    a.level == b.level
        && a.scale.to_bits() == b.scale.to_bits()
        && a.c0.data() == b.c0.data()
        && a.c1.data() == b.c1.data()
}

fn test_model(seed: u64, l: usize) -> (HrfModel, Arc<CkksParams>) {
    let mut rng = Xoshiro256pp::new(seed);
    let nf = synth_forest(4, l, 2, 8, &mut rng);
    let params = Arc::new(CkksParams::build("dag-n4096-d4", 4096, 60, 40, 4, 3.2));
    let hm = HrfModel::from_neural_forest(&nf, 8, params.slots()).unwrap();
    (hm, params)
}

fn slot_params(hm: &HrfModel) -> SlotModelParams {
    let plan = hm.plan;
    SlotModelParams::from_hrf(
        hm,
        SlotShape {
            s: plan.slots,
            k: plan.k,
            c: plan.c,
            m: hm.act_coeffs.len(),
            b: 8,
        },
    )
    .unwrap()
}

fn slot_inputs(hm: &HrfModel, b: usize, rng: &mut Xoshiro256pp) -> Vec<Vec<f32>> {
    (0..b)
        .map(|_| {
            let x: Vec<f64> = (0..8).map(|_| rng.uniform(0.0, 1.0)).collect();
            reshuffle_and_pack(hm, &x).iter().map(|&v| v as f32).collect()
        })
        .collect()
}

/// Independent oracle for one op's (reads, writes) over the DAG's
/// location space: registers `0..n_regs`, hoist slots `n_regs..`.
/// Mirrors the executor's semantics — `AddAssign` mutates **both**
/// operands (CKKS scale adoption), in-place ops write their register.
fn oracle_access(op: &ScheduleOp, n_regs: usize) -> (Vec<usize>, Vec<usize>) {
    use ScheduleOp::*;
    let h = |r: usize| n_regs + r;
    match *op {
        LoadInput { dst, .. } => (vec![], vec![dst]),
        Rotate { dst, src, .. }
        | MulPlainCached { dst, src, .. }
        | MulPlainRescale { dst, src, .. }
        | PolyActivation { dst, src }
        | RotateSumGrouped { dst, src, .. } => (vec![src], vec![dst]),
        Hoist { src } => (vec![src], vec![h(src)]),
        RotateHoisted { dst, src, .. } | ExtractScore { dst, src, .. } => {
            (vec![src, h(src)], vec![dst])
        }
        AddAssign { dst, src } => (vec![], vec![dst, src]),
        SubPlain { reg, .. } | AddPlain { reg, .. } | AddConst { reg, .. } | Rescale { reg } => {
            (vec![], vec![reg])
        }
    }
}

/// Brute-force hazard check: every conflicting op pair (shared
/// location, at least one side writing) must be ordered by a DAG path.
fn assert_conflicts_ordered(sched: &HrfSchedule, dag: &ScheduleDag, what: &str) {
    let n = sched.ops.len();
    let access: Vec<(Vec<usize>, Vec<usize>)> = sched
        .ops
        .iter()
        .map(|(_, op)| oracle_access(op, sched.n_regs))
        .collect();
    // Transitive closure as bitsets, filled back-to-front (every edge
    // points forward, so reach[s] is final when node i unions it in).
    let words = n.div_ceil(64);
    let mut reach = vec![vec![0u64; words]; n];
    for i in (0..n).rev() {
        let (head, tail) = reach.split_at_mut(i + 1);
        let ri = &mut head[i];
        for &s in &dag.succs[i] {
            ri[s / 64] |= 1 << (s % 64);
            for (w, &v) in ri.iter_mut().zip(&tail[s - i - 1]) {
                *w |= v;
            }
        }
    }
    let overlaps = |a: &[usize], b: &[usize]| a.iter().any(|x| b.contains(x));
    for i in 0..n {
        let (ri, wi) = &access[i];
        for j in i + 1..n {
            let (rj, wj) = &access[j];
            let conflict =
                overlaps(wi, rj) || overlaps(wi, wj) || overlaps(ri, wj);
            if conflict {
                assert!(
                    (reach[i][j / 64] >> (j % 64)) & 1 == 1,
                    "{what}: conflicting ops {i} and {j} unordered in DAG"
                );
            }
        }
    }
}

#[test]
fn dag_well_formed_on_compiled_schedules() {
    let (hm, _) = test_model(7001, 3);
    let b_max = hm.plan.groups.min(4);
    for (pname, pipeline) in [
        ("empty", PassPipeline::empty as fn() -> PassPipeline),
        ("standard", PassPipeline::standard),
        ("aggressive", PassPipeline::aggressive),
    ] {
        let server = HrfServer::with_passes(hm.clone(), pipeline());
        for b in [1usize, 2, b_max] {
            for fold in [true, false] {
                let sched = server.schedule(b, fold);
                let dag = server.dag(b, fold);
                let what = format!("{pname} b={b} fold={fold}");
                dag.validate(&sched).unwrap_or_else(|e| panic!("{what}: {e}"));
                assert_conflicts_ordered(&sched, &dag, &what);
                let stats = server.dag_stats(b, fold);
                assert_eq!(stats.ops, sched.ops.len(), "{what}");
                assert!(stats.waves >= 1 && stats.waves <= stats.ops, "{what}");
                assert!(stats.width >= 1 && stats.width <= stats.ops, "{what}");
                assert!(
                    stats.waves < stats.ops,
                    "{what}: a compiled schedule must expose some op-parallelism"
                );
            }
        }
    }
}

#[test]
fn slot_backend_parallel_matches_serial_exactly() {
    let (hm, _) = test_model(7101, 3);
    let params = slot_params(&hm);
    let mut rng = Xoshiro256pp::new(7102);
    let b_max = hm.plan.groups.min(4);
    let server = HrfServer::new(hm.clone());
    let cost = CostModel::static_default();
    for b in [1usize, 2, b_max] {
        let singles = slot_inputs(&hm, b, &mut rng);
        let sched = server.schedule(b, true);
        let dag = ScheduleDag::build(&sched);
        let mut be = SlotBackend::new(&params, &singles);
        let serial = Engine::run(&sched, &mut be);
        let want: Vec<u32> = Engine::read_outputs(&sched, &serial, &mut be)
            .iter()
            .map(|f| f.to_bits())
            .collect();
        for workers in [1usize, 2, 4] {
            let (run, mut backends) =
                Engine::run_parallel(&sched, &dag, &cost, workers, |_| {
                    SlotBackend::new(&params, &singles)
                })
                .unwrap();
            assert_eq!(run.counts, serial.counts, "b={b} w={workers}");
            let got: Vec<u32> = Engine::read_outputs(&sched, &run, &mut backends[0])
                .iter()
                .map(|f| f.to_bits())
                .collect();
            assert_eq!(got, want, "b={b} w={workers}: f32 outputs must be bit-identical");
        }
    }
}

/// Slot backend that fails on the first activation — injected fault
/// for the driver's panic path.
struct FaultyBackend<'a>(SlotBackend<'a>);

impl ScheduleBackend for FaultyBackend<'_> {
    type Value = Vec<f32>;
    type Hoisted = ();
    type Score = f32;

    fn load_input(&mut self, input: usize) -> Vec<f32> {
        self.0.load_input(input)
    }
    fn rotate(&mut self, src: &Vec<f32>, step: usize) -> Vec<f32> {
        self.0.rotate(src, step)
    }
    fn hoist(&mut self, src: &Vec<f32>) {
        self.0.hoist(src)
    }
    fn rotate_hoisted(&mut self, src: &Vec<f32>, hoisted: &(), step: usize) -> Vec<f32> {
        self.0.rotate_hoisted(src, hoisted, step)
    }
    fn add_assign(&mut self, dst: &mut Vec<f32>, src: &mut Vec<f32>) {
        self.0.add_assign(dst, src)
    }
    fn sub_plain(&mut self, reg: &mut Vec<f32>, operand: cryptotree::hrf::PlainOperand) {
        self.0.sub_plain(reg, operand)
    }
    fn add_plain(&mut self, reg: &mut Vec<f32>, operand: cryptotree::hrf::PlainOperand) {
        self.0.add_plain(reg, operand)
    }
    fn mul_plain_cached(
        &mut self,
        src: &Vec<f32>,
        operand: cryptotree::hrf::PlainOperand,
    ) -> Vec<f32> {
        self.0.mul_plain_cached(src, operand)
    }
    fn add_const(&mut self, reg: &mut Vec<f32>, value: f64) {
        self.0.add_const(reg, value)
    }
    fn rescale(&mut self, reg: &mut Vec<f32>) {
        self.0.rescale(reg)
    }
    fn poly_activation(&mut self, _src: &Vec<f32>) -> Vec<f32> {
        panic!("injected activation fault")
    }
    fn rotate_sum_grouped(&mut self, src: &Vec<f32>, span: usize) -> Vec<f32> {
        self.0.rotate_sum_grouped(src, span)
    }
    fn read_score(&mut self, value: &Vec<f32>, slot: usize) -> f32 {
        self.0.read_score(value, slot)
    }
}

#[test]
fn worker_panic_surfaces_as_typed_error() {
    let (hm, _) = test_model(7201, 3);
    let params = slot_params(&hm);
    let mut rng = Xoshiro256pp::new(7202);
    let singles = slot_inputs(&hm, 2, &mut rng);
    let server = HrfServer::new(hm.clone());
    let sched = server.schedule(2, true);
    let dag = ScheduleDag::build(&sched);
    // Every HRF schedule activates, so the fault always fires; the
    // driver must join all workers and return the typed error — this
    // test completing at all is the no-hang claim.
    let res = Engine::run_parallel(&sched, &dag, &CostModel::static_default(), 4, |_| {
        FaultyBackend(SlotBackend::new(&params, &singles))
    });
    match res {
        Err(DagExecError::WorkerPanic { message, .. }) => {
            assert!(message.contains("injected activation fault"), "got: {message}")
        }
        Ok(_) => panic!("faulty backend must not complete"),
    }
}

#[test]
fn reuse_registers_shrinks_live_peak() {
    let (hm, _) = test_model(7301, 3);
    let params = slot_params(&hm);
    let mut rng = Xoshiro256pp::new(7302);
    let server_raw = HrfServer::with_passes(hm.clone(), PassPipeline::empty());
    let b = hm.plan.groups.min(4);
    let raw = server_raw.schedule(b, true);
    let mut reused = (*raw).clone();
    assert!(ReuseRegisters.run(&mut reused), "pass must rewrite the batch schedule");
    assert!(
        reused.n_regs < raw.n_regs,
        "live peak {} must drop below {}",
        reused.n_regs,
        raw.n_regs
    );
    let singles = slot_inputs(&hm, b, &mut rng);
    let before = params.run_schedule(&raw, &singles);
    let after = params.run_schedule(&reused, &singles);
    assert_eq!(before, after, "register reuse changed results");
    // And the renamed schedule still parallelizes correctly.
    let dag = ScheduleDag::build(&reused);
    dag.validate(&reused).unwrap();
    assert_conflicts_ordered(&reused, &dag, "reused");
}

#[test]
fn ckks_dag_grid_bit_identical_to_serial() {
    let (hm, params) = test_model(7401, 3);
    let ctx = CkksContext::new(params);
    let enc = Encoder::new(&ctx);
    let plan = hm.plan;
    let mut kg = KeyGenerator::new(&ctx, 7402);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let b_max = plan.groups.min(3);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(b_max));
    let mut client = HrfClient::new(Encryptor::new(pk, 7403), Decryptor::new(kg.secret_key()));
    let mut rng = Xoshiro256pp::new(7404);

    let server_raw = HrfServer::with_passes(hm.clone(), PassPipeline::empty());
    let server_agg = HrfServer::with_passes(hm.clone(), PassPipeline::aggressive());

    for b in [1usize, 2, b_max] {
        let xs: Vec<Vec<f64>> = (0..b)
            .map(|_| (0..8).map(|_| rng.uniform(0.0, 1.0)).collect())
            .collect();
        let cts: Vec<Ciphertext> = xs
            .iter()
            .map(|x| client.encrypt_input(&ctx, &enc, &hm, x))
            .collect();
        for (pname, server) in [("raw", &server_raw), ("aggressive", &server_agg)] {
            server.set_op_workers(1);
            ctx.set_workers(1);
            let mut ev = Evaluator::new(ctx.clone());
            let ex = server.execute(&mut ev, &enc, &EncRequest::group(&cts), &rlk, &gk);
            let base_counts = ex.counts;
            let base = ex.into_class_scores();
            for ow in [1usize, 2, 4] {
                for cw in [1usize, 4] {
                    if ow == 1 && cw == 1 {
                        continue; // the baseline itself
                    }
                    server.set_op_workers(ow);
                    ctx.set_workers(cw);
                    let mut ev = Evaluator::new(ctx.clone());
                    let ex =
                        server.execute(&mut ev, &enc, &EncRequest::group(&cts), &rlk, &gk);
                    assert_eq!(
                        ex.counts, base_counts,
                        "{pname} B={b} ow={ow} cw={cw}: op accounting drifted"
                    );
                    for (got, want) in ex.into_class_scores().iter().zip(&base) {
                        assert!(
                            ct_bits_equal(got, want),
                            "{pname} B={b} ow={ow} cw={cw}: ciphertext bits deviate from serial"
                        );
                    }
                }
            }
            server.set_op_workers(1);
        }
        ctx.set_workers(1);
    }
}
