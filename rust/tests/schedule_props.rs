//! Properties of the compiled HE op schedule (ISSUE 3):
//!
//! (a) **Bit-identity** — executing the compiled schedule produces
//!     ciphertexts bit-identical to the retained hand-written
//!     reference path for B ∈ {1, 2, max} on random models (the
//!     folded schedule's per-class outputs equal the reference
//!     pack+eval outputs limb for limb).
//! (b) **Key sufficiency** — Galois keys generated from the
//!     schedule-derived `eval_key_requirements(b)` (and nothing more)
//!     run the folded batched evaluation without a rotation miss and
//!     decrypt correctly.
//! (c) **The fold saves exactly C·(B−1) rotations** — measured by the
//!     evaluator's counters against the legacy eval+extract path, and
//!     predicted by the dry-run interpreter.
//!
//! Plus: the dry-run interpreter's per-layer counts equal measured
//! execution exactly, and `poly_op_counts` mirrors
//! `eval_poly_power_basis`'s measured counters.

use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{Ciphertext, CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::hrf::client::{reshuffle_and_pack, HrfClient};
use cryptotree::hrf::schedule::poly_op_counts;
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::activation::chebyshev_fit_tanh;
use cryptotree::nrf::{Activation, NeuralForest, NeuralTree};
use cryptotree::rng::Xoshiro256pp;
use std::sync::Arc;

fn synth_forest(k: usize, l: usize, c: usize, d: usize, rng: &mut Xoshiro256pp) -> NeuralForest {
    let trees = (0..l)
        .map(|_| NeuralTree {
            tau: (0..k - 1).map(|_| rng.next_index(d)).collect(),
            t: (0..k - 1).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            v: (0..k)
                .map(|_| (0..k - 1).map(|_| rng.uniform(-0.25, 0.25)).collect())
                .collect(),
            b: (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            w: (0..c)
                .map(|_| (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect())
                .collect(),
            beta: (0..c).map(|_| rng.uniform(-0.2, 0.2)).collect(),
            real_leaves: k,
            n_classes: c,
        })
        .collect();
    NeuralForest {
        trees,
        alphas: (0..l).map(|_| rng.uniform(0.1, 1.0)).collect(),
        k,
        n_classes: c,
        activation: Activation::Poly {
            coeffs: vec![0.0, 1.0], // identity: fits the depth-4 ring
        },
    }
}

fn rand_x(d: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..d).map(|_| rng.uniform(0.0, 1.0)).collect()
}

fn ct_bits_equal(a: &Ciphertext, b: &Ciphertext) -> bool {
    a.level == b.level
        && a.scale.to_bits() == b.scale.to_bits()
        && a.c0.data() == b.c0.data()
        && a.c1.data() == b.c1.data()
}

struct World {
    ctx: cryptotree::ckks::rns::ContextRef,
    enc: Encoder,
    client: HrfClient,
    server: HrfServer,
    rlk: cryptotree::ckks::keys::RelinKey,
    gk: cryptotree::ckks::keys::GaloisKeys,
    d: usize,
}

/// Cheap depth-4 world with full-batch legacy key coverage.
fn world(seed: u64) -> World {
    let mut rng = Xoshiro256pp::new(seed);
    let d = 8;
    let nf = synth_forest(4, 4, 2, d, &mut rng);
    let params = Arc::new(CkksParams::build("sched-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;
    let mut kg = KeyGenerator::new(&ctx, seed + 1);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    // Legacy superset: covers eval + placement + extraction for every
    // batch size these tests use, so both the reference and the
    // compiled paths run under one session (capped at 8 to keep
    // keygen fast on the 64-group plan).
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(8.min(plan.groups)));
    let client = HrfClient::new(Encryptor::new(pk, seed + 2), Decryptor::new(kg.secret_key()));
    World {
        ctx,
        enc,
        client,
        server: HrfServer::new(hm),
        rlk,
        gk,
        d,
    }
}

/// (a) Folded schedule outputs are bit-identical to the reference
/// pack+eval path for B ∈ {1, 2, max-capped}.
#[test]
fn compiled_schedule_bit_identical_to_reference() {
    let mut rng = Xoshiro256pp::new(7001);
    let mut w = world(7100);
    let plan = w.server.model.plan;
    let b_max = plan.groups.min(6); // cap runtime; still multi-chunk
    for b in [1usize, 2, b_max] {
        let xs: Vec<Vec<f64>> = (0..b).map(|_| rand_x(w.d, &mut rng)).collect();
        let cts: Vec<Ciphertext> = xs
            .iter()
            .map(|x| w.client.encrypt_input(&w.ctx, &w.enc, &w.server.model, x))
            .collect();
        let mut ev = Evaluator::new(w.ctx.clone());
        let ex = w
            .server
            .execute(&mut ev, &w.enc, &EncRequest::group(&cts), &w.rlk, &w.gk);
        let counts = ex.counts;
        let folded = ex.into_class_scores();
        // Reference: hand-written pack + eval (no extraction).
        let mut ev_ref = Evaluator::new(w.ctx.clone());
        let packed = if b == 1 {
            cts[0].clone()
        } else {
            w.server.pack_group(&mut ev_ref, &cts, &w.gk)
        };
        let (reference, _) = w
            .server
            .eval_reference(&mut ev_ref, &w.enc, &packed, &w.rlk, &w.gk);
        assert_eq!(folded.len(), reference.len());
        for (f, r) in folded.iter().zip(&reference) {
            assert!(
                ct_bits_equal(f, r),
                "B={b}: compiled schedule deviates from reference bits"
            );
        }
        // The executor's measured counts equal the dry-run prediction.
        assert_eq!(
            counts,
            w.server.predicted_counts(b, true),
            "B={b}: dry-run prediction deviates from measured execution"
        );
        // And every sample decrypts to its own correct score.
        for (g, x) in xs.iter().enumerate() {
            let (scores, _) =
                w.client
                    .decrypt_scores_at(&w.ctx, &w.enc, &folded, plan.score_slot(g));
            let expect = w
                .server
                .model
                .forward_slots_plain(&reshuffle_and_pack(&w.server.model, x));
            for (s, e) in scores.iter().zip(&expect) {
                assert!((s - e).abs() < 5e-3, "B={b} sample {g}: {scores:?} vs {expect:?}");
            }
        }
    }
}

/// (b) Keys generated from exactly the schedule-derived requirement
/// set suffice: no rotation miss (a miss panics inside the
/// evaluator), correct per-sample results.
#[test]
fn schedule_derived_key_requirements_suffice() {
    let mut rng = Xoshiro256pp::new(7002);
    let d = 8;
    let nf = synth_forest(4, 3, 2, d, &mut rng);
    let params = Arc::new(CkksParams::build("schedkeys-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;
    let server = HrfServer::new(hm);
    let b = plan.groups.min(4);
    assert!(b >= 2);

    let mut kg = KeyGenerator::new(&ctx, 7003);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    // EXACTLY the derived set — no extraction steps, nothing extra.
    let req = server.eval_key_requirements(b);
    let gk = kg.gen_galois_keys(&ctx, &req);
    assert!(server.can_batch(&gk, b), "requirements must satisfy can_batch");
    // The derived set is a strict subset of the legacy formula for
    // B > 1 (extraction steps dropped).
    let legacy = plan.rotations_needed_batched(b);
    assert!(req.iter().all(|r| legacy.contains(r)));
    assert!(
        req.len() < legacy.len(),
        "folded requirements should drop extraction steps"
    );

    let mut client = HrfClient::new(Encryptor::new(pk, 7004), Decryptor::new(kg.secret_key()));
    let mut ev = Evaluator::new(ctx.clone());
    let xs: Vec<Vec<f64>> = (0..b).map(|_| rand_x(d, &mut rng)).collect();
    let cts: Vec<Ciphertext> = xs
        .iter()
        .map(|x| client.encrypt_input(&ctx, &enc, &server.model, x))
        .collect();
    let outs = server
        .execute(&mut ev, &enc, &EncRequest::group(&cts), &rlk, &gk)
        .into_class_scores();
    for (g, x) in xs.iter().enumerate() {
        let (scores, _) = client.decrypt_scores_at(&ctx, &enc, &outs, plan.score_slot(g));
        let expect = server
            .model
            .forward_slots_plain(&reshuffle_and_pack(&server.model, x));
        for (s, e) in scores.iter().zip(&expect) {
            assert!((s - e).abs() < 5e-3, "sample {g}: {scores:?} vs {expect:?}");
        }
    }
}

/// (c) Measured rotation counts: the folded schedule executes exactly
/// C·(B−1) fewer rotations than the legacy eval+extract path, at
/// equal pack/eval cost.
#[test]
fn folded_schedule_saves_c_times_b_minus_1_rotations() {
    let mut rng = Xoshiro256pp::new(7005);
    let mut w = world(7200);
    let plan = w.server.model.plan;
    for b in [2usize, 3, plan.groups.min(5)] {
        let xs: Vec<Vec<f64>> = (0..b).map(|_| rand_x(w.d, &mut rng)).collect();
        let cts: Vec<Ciphertext> = xs
            .iter()
            .map(|x| w.client.encrypt_input(&w.ctx, &w.enc, &w.server.model, x))
            .collect();

        // Legacy eval+extract (hand-written reference).
        let mut ev_legacy = Evaluator::new(w.ctx.clone());
        let _ = w
            .server
            .eval_batch_reference(&mut ev_legacy, &w.enc, &cts, &w.rlk, &w.gk);
        let legacy_rot = ev_legacy.counts.rotate;

        // Folded compiled schedule.
        let mut ev_folded = Evaluator::new(w.ctx.clone());
        let _ = w
            .server
            .execute(&mut ev_folded, &w.enc, &EncRequest::group(&cts), &w.rlk, &w.gk);
        let folded_rot = ev_folded.counts.rotate;

        let saving = (plan.c * (b - 1)) as u64;
        assert_eq!(
            legacy_rot - folded_rot,
            saving,
            "B={b}: folded must save exactly C·(B−1) rotations"
        );

        // The unfolded schedule (legacy slot-0 contract) matches the
        // reference count exactly — the fold, not the compilation, is
        // what saves.
        let mut ev_unfolded = Evaluator::new(w.ctx.clone());
        let _ = w
            .server
            .execute(&mut ev_unfolded, &w.enc, &EncRequest::group_slot0(&cts), &w.rlk, &w.gk);
        assert_eq!(ev_unfolded.counts.rotate, legacy_rot, "B={b}: unfolded count");

        // Dry-run predictions agree with both measurements.
        assert_eq!(
            w.server.predicted_counts(b, true).total().rotate,
            folded_rot,
            "B={b}: folded prediction"
        );
        assert_eq!(
            w.server.predicted_counts(b, false).total().rotate,
            legacy_rot,
            "B={b}: unfolded prediction"
        );
    }
}

/// The unfolded schedule preserves the slot-0 per-sample contract
/// (its hoisted extraction is numerically equivalent to the legacy
/// plain rotations). Exercised through the deprecated `eval_batch`
/// wrapper on purpose — the wrapper contract is pinned here.
#[test]
#[allow(deprecated)]
fn unfolded_schedule_keeps_slot0_contract() {
    let mut rng = Xoshiro256pp::new(7006);
    let mut w = world(7300);
    let b = w.server.model.plan.groups.min(3);
    let xs: Vec<Vec<f64>> = (0..b).map(|_| rand_x(w.d, &mut rng)).collect();
    let cts: Vec<Ciphertext> = xs
        .iter()
        .map(|x| w.client.encrypt_input(&w.ctx, &w.enc, &w.server.model, x))
        .collect();
    let mut ev = Evaluator::new(w.ctx.clone());
    let (per_sample, _) = w.server.eval_batch(&mut ev, &w.enc, &cts, &w.rlk, &w.gk);
    assert_eq!(per_sample.len(), b);
    for (g, (outs, x)) in per_sample.iter().zip(&xs).enumerate() {
        let (scores, _) = w.client.decrypt_scores(&w.ctx, &w.enc, outs);
        let expect = w
            .server
            .model
            .forward_slots_plain(&reshuffle_and_pack(&w.server.model, x));
        for (s, e) in scores.iter().zip(&expect) {
            assert!((s - e).abs() < 5e-3, "sample {g}: {scores:?} vs {expect:?}");
        }
    }
}

/// `poly_op_counts` mirrors the evaluator's measured counters for a
/// spread of coefficient shapes (sparse, dense, near-zero tails).
#[test]
fn poly_op_counts_match_measured() {
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let mut kg = KeyGenerator::new(&ctx, 7007);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let mut encryptor = Encryptor::new(pk, 7008);
    let mut ev = Evaluator::new(ctx.clone());
    let n = enc.slots();
    let mut rng = Xoshiro256pp::new(7009);
    let x: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let ct = encryptor.encrypt_slots(&ctx, &enc, &x);
    let cases: Vec<Vec<f64>> = vec![
        vec![0.0, 1.0],
        vec![0.5, -0.3, 0.2],
        vec![0.1, 0.7, -0.2, 0.05],
        vec![0.1, 0.7, -0.2, 0.05, -0.3],
        chebyshev_fit_tanh(3.0, 4),
        vec![0.0, 0.25, 0.0, 0.125, 0.0, 0.0625], // odd, deg 5
    ];
    for coeffs in cases {
        let before = ev.counts;
        let _ = ev.eval_poly_power_basis(&enc, &ct, &coeffs, &rlk);
        let measured = ev.counts.diff(&before);
        assert_eq!(
            measured,
            poly_op_counts(&coeffs),
            "dry-run mirror deviates for coeffs {coeffs:?}"
        );
    }
}
