//! Observability-plane properties.
//!
//! 1. The op-profile engine backend is *exact*: an
//!    `HrfServer::execute_profiled` run attributes every evaluator op
//!    to a `(segment, op kind)` cell, and the profile's aggregated
//!    multiplicities equal both the execution's own segment accounting
//!    and the dry-run `CountingBackend` prediction
//!    (`HrfServer::predicted_counts`) — the measured Table 1 cannot
//!    drift from the predicted one.
//! 2. Span traces through a live coordinator tell a coherent story:
//!    in-process requests stamp Admitted → Batched → Executing →
//!    Responded in monotone order, requests flushed together share a
//!    flush id with the right group size, and the plain path's flush
//!    is distinct from the encrypted one's.

use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, Evaluator, KeyGenerator};
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer, Segment};
use cryptotree::nrf::activation::Activation;
use cryptotree::nrf::NeuralForest;
use cryptotree::obs::{OpProfile, TraceKind, TracePhase};
use std::sync::Arc;
use std::time::Duration;

struct World {
    ctx: cryptotree::ckks::rns::ContextRef,
    enc: Encoder,
    client: HrfClient,
    server: Arc<HrfServer>,
    rlk: cryptotree::ckks::RelinKey,
    gk: cryptotree::ckks::GaloisKeys,
    ds: cryptotree::data::Dataset,
}

/// The cheap fixture shared by both tests: tiny ring (N=4096, depth 4,
/// test-grade security), identity activation — the observability
/// plumbing is under test, not the numerics. Galois keys cover both
/// single-sample execution and 2-sample server-side packing so the
/// coordinator's enc-batcher can serve a flushed pair as one chunk.
fn world() -> World {
    let ds = adult::generate(400, 716);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 4,
            tree: cryptotree::forest::tree::TreeConfig {
                max_depth: 2,
                ..Default::default()
            },
            ..Default::default()
        },
        717,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: vec![0.0, 1.0],
        },
    );
    let params = Arc::new(CkksParams::build("obs-test-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
    let plan = model.plan;
    let mut kg = KeyGenerator::new(&ctx, 718);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let mut steps = plan.rotations_needed();
    steps.extend(plan.rotations_needed_batched(2));
    steps.sort_unstable();
    steps.dedup();
    let gk = kg.gen_galois_keys(&ctx, &steps);
    let client = HrfClient::new(Encryptor::new(pk, 719), Decryptor::new(kg.secret_key()));
    World {
        ctx,
        enc,
        client,
        server: Arc::new(HrfServer::new(model)),
        rlk,
        gk,
        ds,
    }
}

/// Acceptance property from the ISSUE: op multiplicities recorded by
/// the profiling backend equal the `CountingBackend` dry-run
/// prediction, overall and per segment.
#[test]
fn profiled_execution_matches_dry_run_prediction() {
    let mut w = world();
    let ct = w
        .client
        .encrypt_input(&w.ctx, &w.enc, &w.server.model, &w.ds.x[0]);
    let mut ev = Evaluator::new(w.ctx.clone());
    let mut profile = OpProfile::default();

    let exec = w.server.execute_profiled(
        &mut ev,
        &w.enc,
        &EncRequest::single(&ct),
        &w.rlk,
        &w.gk,
        &mut profile,
    );

    // Measured == engine accounting == dry-run prediction.
    let predicted = w.server.predicted_counts(1, true);
    assert_eq!(exec.counts, predicted, "execution deviates from dry run");
    assert_eq!(
        profile.layer_counts(),
        exec.counts,
        "profile multiplicities deviate from the engine's segment accounting"
    );
    assert_eq!(profile.op_counts(), predicted.total());

    // Per-segment agreement, bucket by bucket.
    let measured = profile.layer_counts();
    for seg in [
        Segment::Pack,
        Segment::Layer1,
        Segment::Act1,
        Segment::Layer2,
        Segment::Act2,
        Segment::Layer3,
        Segment::Extract,
    ] {
        assert_eq!(
            measured.bucket(seg),
            predicted.bucket(seg),
            "segment {seg:?} multiplicities disagree"
        );
    }

    // The timing side is sane: real nanoseconds, coherent quantiles.
    assert!(!profile.is_empty());
    assert!(profile.total_time() > Duration::ZERO);
    let rows = profile.rows();
    assert!(!rows.is_empty());
    for r in &rows {
        assert!(r.calls > 0);
        assert!(r.p50 <= r.p99, "row {:?}/{:?} p50 > p99", r.segment, r.kind);
        assert!(r.total >= r.mean);
    }
    assert!(profile.table().contains("segment"));

    // Profiles accumulate: a second identical run doubles the counts.
    let _ = w.server.execute_profiled(
        &mut ev,
        &w.enc,
        &EncRequest::single(&ct),
        &w.rlk,
        &w.gk,
        &mut profile,
    );
    let mut twice = predicted.total();
    twice += predicted.total();
    assert_eq!(profile.op_counts(), twice, "profile must accumulate across runs");
}

/// End-to-end trace semantics through a live coordinator: two
/// encrypted requests batched together share one flush id (group 2),
/// the plain request rides its own flush, and every completed trace
/// stamps the in-process phases in monotone order.
#[test]
fn coordinator_traces_share_flush_ids_and_stay_monotone() {
    let mut w = world();
    let sessions = Arc::new(SessionManager::new());
    let sid = sessions.register(w.rlk.clone(), w.gk.clone());
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            queue_capacity: 16,
            enc_batch: 2,
            adaptive_enc_batch: false,
            // Plain path flushes on arrival (the lone plain request
            // below must not wait out `batch_delay`).
            max_batch: 1,
            // Generous flush window, idle-flush disabled: the pair
            // submitted back-to-back below must land in ONE flush.
            batch_delay: Duration::from_secs(2),
            idle_flush: Duration::from_secs(5),
            trace_capacity: 64,
            ..Default::default()
        },
        w.ctx.clone(),
        w.server.clone(),
        sessions,
        None,
    );
    assert!(coord.metrics.trace.enabled());

    let ct0 = w
        .client
        .encrypt_input(&w.ctx, &w.enc, &w.server.model, &w.ds.x[0]);
    let ct1 = w
        .client
        .encrypt_input(&w.ctx, &w.enc, &w.server.model, &w.ds.x[1]);
    let rx0 = coord.submit_encrypted(sid, ct0).unwrap();
    let rx1 = coord.submit_encrypted(sid, ct1).unwrap();
    assert!(rx0.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
    assert!(rx1.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
    let prx = coord.submit_plain(w.ds.x[2].clone()).unwrap();
    assert!(prx.recv_timeout(Duration::from_secs(60)).unwrap().is_ok());

    // Workers record each trace before sending the response, so by now
    // all three are in the ring.
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.encrypted_completed, 2);
    assert_eq!(snap.plain_completed, 1);
    assert_eq!(snap.traces_recorded, 3);
    assert_eq!(snap.traces_dropped, 0);

    let traces = coord.metrics.trace.snapshot();
    assert_eq!(traces.len(), 3);
    for t in &traces {
        // In-process submissions never touch the wire: no socket-side
        // phases, and the timeline starts at admission.
        assert_eq!(t.phase(TracePhase::Accepted), None);
        assert_eq!(t.phase(TracePhase::Decoded), None);
        let offsets: Vec<u64> = [
            TracePhase::Admitted,
            TracePhase::Batched,
            TracePhase::Executing,
            TracePhase::Responded,
        ]
        .iter()
        .map(|&p| {
            t.phase(p)
                .unwrap_or_else(|| panic!("{:?} missing phase {p:?}", t.kind))
                .as_micros() as u64
        })
        .collect();
        assert!(
            offsets.windows(2).all(|p| p[0] <= p[1]),
            "{:?} phases not monotone: {offsets:?}",
            t.kind
        );
        assert!(t.queue_time().is_some() && t.service_time().is_some());
    }
    // Ring order is completion order; ids are sink-unique and increase.
    assert!(traces.windows(2).all(|p| p[0].id < p[1].id));

    let enc_traces: Vec<_> = traces
        .iter()
        .filter(|t| t.kind == TraceKind::Encrypted)
        .collect();
    let plain_traces: Vec<_> = traces
        .iter()
        .filter(|t| t.kind == TraceKind::Plain)
        .collect();
    assert_eq!((enc_traces.len(), plain_traces.len()), (2, 1));

    // The batched pair shares one flush of group 2 …
    let (fid_a, group_a) = enc_traces[0].flush.expect("batched request has a flush id");
    let (fid_b, group_b) = enc_traces[1].flush.expect("batched request has a flush id");
    assert_eq!(fid_a, fid_b, "requests flushed together must share a flush id");
    assert_eq!((group_a, group_b), (2, 2));
    // … and the plain request rides a different flush of its own.
    let (plain_fid, plain_group) = plain_traces[0].flush.expect("plain flush id");
    assert_ne!(plain_fid, fid_a, "distinct flushes must not share an id");
    assert_eq!(plain_group, 1);

    coord.shutdown();
}
