//! End-to-end tests for the networked serving tier: codec
//! round-trips, defensive decoding, and real-socket sessions
//! including the eviction → re-register recovery protocol over the
//! wire.

use cryptotree::ckks::{Ciphertext, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::coordinator::metrics::Metrics;
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager, SubmitError};
use cryptotree::hrf::client::{reshuffle_and_pack, EvalKeys, HrfClient};
use cryptotree::hrf::EncScores;
use cryptotree::keycache::KeyCacheConfig;
use cryptotree::net::client::{NetClient, NetError};
use cryptotree::net::codec::{
    decode_request, decode_response, encode_request, encode_response, CodecError, ModelInfo,
    Request, Response, WireError,
};
use cryptotree::net::server::{NetServer, NetServerConfig};
use cryptotree::net::workload::{self, WorkloadSpec};
use cryptotree::obs::{TraceKind, TracePhase, TraceRecord};
use std::sync::Arc;
use std::time::Duration;

fn small_spec() -> WorkloadSpec {
    WorkloadSpec {
        params: "demo".to_string(),
        trees: 2,
        depth: 2,
        rows: 64,
        seed: 7,
    }
}

fn assert_polys_eq(a: &Ciphertext, b: &Ciphertext) {
    assert_eq!(a.level, b.level);
    assert_eq!(a.scale.to_bits(), b.scale.to_bits());
    assert_eq!(a.c0.data(), b.c0.data());
    assert_eq!(a.c1.data(), b.c1.data());
}

/// Every request and response variant survives encode → decode with
/// bit-identical crypto payloads.
#[test]
fn codec_roundtrips_every_variant() {
    let wl = workload::build(&small_spec());
    let ctx = &wl.ctx;
    let enc = Encoder::new(ctx);
    let mut kg = KeyGenerator::new(ctx, 11);
    let pk = kg.gen_public_key(ctx);
    let keys = EvalKeys {
        relin: kg.gen_relin_key(ctx),
        galois: kg.gen_galois_keys(ctx, &wl.server.eval_key_requirements(2)),
    };
    let mut encryptor = Encryptor::new(pk, 12);
    let slots = reshuffle_and_pack(&wl.server.model, &wl.data.x[0]);
    let ct = encryptor.encrypt_slots(ctx, &enc, &slots);

    // RegisterKeys: relin + every Galois key round-trips, and the
    // decoder recomputes (not trusts) the Galois elements.
    let req = decode_request(
        &encode_request(&Request::RegisterKeys { keys: keys.clone() }),
        ctx,
    )
    .unwrap();
    match req {
        Request::RegisterKeys { keys: got } => {
            assert_eq!(got.relin.0.b.len(), keys.relin.0.b.len());
            assert_eq!(got.galois.keys.len(), keys.galois.keys.len());
            assert_eq!(got.galois.elements, keys.galois.elements);
            for (step, k) in &keys.galois.keys {
                let g = &got.galois.keys[step];
                for (x, y) in k.b.iter().zip(&g.b) {
                    assert_eq!(x.data(), y.data());
                }
            }
        }
        other => panic!("wrong variant: {other:?}"),
    }

    let req = decode_request(
        &encode_request(&Request::SubmitEncrypted {
            session_id: 42,
            ct: ct.clone(),
        }),
        ctx,
    )
    .unwrap();
    match req {
        Request::SubmitEncrypted { session_id, ct: got } => {
            assert_eq!(session_id, 42);
            assert_polys_eq(&got, &ct);
        }
        other => panic!("wrong variant: {other:?}"),
    }

    let req = decode_request(
        &encode_request(&Request::SubmitEncryptedPacked {
            session_id: 7,
            ct: ct.clone(),
            n_samples: 3,
        }),
        ctx,
    )
    .unwrap();
    assert!(matches!(
        req,
        Request::SubmitEncryptedPacked {
            session_id: 7,
            n_samples: 3,
            ..
        }
    ));

    let x = vec![0.25, -1.5, 3.0];
    match decode_request(&encode_request(&Request::SubmitPlain { x: x.clone() }), ctx).unwrap() {
        Request::SubmitPlain { x: got } => assert_eq!(got, x),
        other => panic!("wrong variant: {other:?}"),
    }
    assert!(matches!(
        decode_request(&encode_request(&Request::ModelInfo), ctx).unwrap(),
        Request::ModelInfo
    ));
    assert!(matches!(
        decode_request(
            &encode_request(&Request::Reregister {
                session_id: 9,
                keys: keys.clone()
            }),
            ctx
        )
        .unwrap(),
        Request::Reregister { session_id: 9, .. }
    ));
    assert!(matches!(
        decode_request(&encode_request(&Request::Shutdown), ctx).unwrap(),
        Request::Shutdown
    ));

    // Responses.
    let info = ModelInfo {
        params_name: "serve-n4096-d4".to_string(),
        n: 4096,
        features: 14,
        groups: 8,
        classes: 2,
        rotations: vec![1, 2, 64],
    };
    match decode_response(&encode_response(&Response::ModelInfo(info.clone())), ctx).unwrap() {
        Response::ModelInfo(got) => assert_eq!(got, info),
        other => panic!("wrong variant: {other:?}"),
    }
    let scores = EncScores {
        scores: vec![ct.clone(), ct.clone()],
        slot: 5,
    };
    match decode_response(&encode_response(&Response::EncScores(scores)), ctx).unwrap() {
        Response::EncScores(got) => {
            assert_eq!(got.slot, 5);
            assert_eq!(got.scores.len(), 2);
            assert_polys_eq(&got.scores[0], &ct);
        }
        other => panic!("wrong variant: {other:?}"),
    }
    assert!(matches!(
        decode_response(
            &encode_response(&Response::Registered { session_id: 3 }),
            ctx
        )
        .unwrap(),
        Response::Registered { session_id: 3 }
    ));
    assert!(matches!(
        decode_response(&encode_response(&Response::Reregistered { ok: true }), ctx).unwrap(),
        Response::Reregistered { ok: true }
    ));
    match decode_response(
        &encode_response(&Response::PlainScores(vec![0.5, -0.25])),
        ctx,
    )
    .unwrap()
    {
        Response::PlainScores(got) => assert_eq!(got, vec![0.5, -0.25]),
        other => panic!("wrong variant: {other:?}"),
    }
    for submit in [
        SubmitError::Busy,
        SubmitError::Closed,
        SubmitError::NoSession,
        SubmitError::KeysEvicted,
        SubmitError::BatchTooLarge,
    ] {
        match decode_response(
            &encode_response(&Response::Error(WireError::Submit(submit))),
            ctx,
        )
        .unwrap()
        {
            Response::Error(WireError::Submit(got)) => assert_eq!(got, submit),
            other => panic!("wrong variant: {other:?}"),
        }
    }
    for e in [
        WireError::Server("boom".to_string()),
        WireError::Protocol("bad".to_string()),
    ] {
        match decode_response(&encode_response(&Response::Error(e.clone())), ctx).unwrap() {
            Response::Error(got) => assert_eq!(got, e),
            other => panic!("wrong variant: {other:?}"),
        }
    }
    assert!(matches!(
        decode_response(&encode_response(&Response::ShuttingDown), ctx).unwrap(),
        Response::ShuttingDown
    ));

    // Observability variants.
    assert!(matches!(
        decode_request(&encode_request(&Request::MetricsSnapshot), ctx).unwrap(),
        Request::MetricsSnapshot
    ));
    assert!(matches!(
        decode_request(&encode_request(&Request::TraceDump), ctx).unwrap(),
        Request::TraceDump
    ));
    // A snapshot with non-trivial values in every field class (u64
    // counter, f64 ratio, µs-precision duration) round-trips exactly.
    let mut snap = Metrics::default().snapshot();
    snap.encrypted_completed = 3;
    snap.mean_batch_fill = 1.5;
    snap.batch_fill_ratio = 0.75;
    snap.encrypted_p50 = Duration::from_micros(1234);
    snap.plain_service_mean = Duration::from_micros(9);
    snap.traces_recorded = 11;
    snap.traces_dropped = 7;
    match decode_response(&encode_response(&Response::Metrics(snap.clone())), ctx).unwrap() {
        Response::Metrics(got) => assert_eq!(got, snap),
        other => panic!("wrong variant: {other:?}"),
    }
    let traces = vec![
        TraceRecord {
            id: 1,
            kind: TraceKind::Encrypted,
            flush: Some((4, 2)),
            phases: [Some(0), Some(10), Some(20), Some(30), Some(40), Some(55)],
        },
        TraceRecord {
            id: 2,
            kind: TraceKind::Plain,
            flush: None,
            phases: [None, Some(1), Some(2), None, Some(3), Some(4)],
        },
    ];
    match decode_response(&encode_response(&Response::Traces(traces.clone())), ctx).unwrap() {
        Response::Traces(got) => assert_eq!(got, traces),
        other => panic!("wrong variant: {other:?}"),
    }
    // An unknown trace-kind byte is rejected, not misread.
    let mut bad = encode_response(&Response::Traces(traces));
    bad[5 + 8] = 9; // tag(1) + count(4) + id(8), then the kind byte
    assert!(matches!(
        decode_response(&bad, ctx),
        Err(CodecError::BadTag {
            context: "trace kind",
            tag: 9
        })
    ));
}

/// Defensive decoding: truncation, trailing bytes, unknown tags, and
/// out-of-range polynomial residues are all rejected — a malicious
/// client cannot feed invalid limbs into the NTT kernels.
#[test]
fn codec_rejects_malformed_payloads() {
    let wl = workload::build(&small_spec());
    let ctx = &wl.ctx;
    let enc = Encoder::new(ctx);
    let mut kg = KeyGenerator::new(ctx, 21);
    let pk = kg.gen_public_key(ctx);
    let mut encryptor = Encryptor::new(pk, 22);
    let slots = reshuffle_and_pack(&wl.server.model, &wl.data.x[1]);
    let ct = encryptor.encrypt_slots(ctx, &enc, &slots);
    let good = encode_request(&Request::SubmitEncrypted { session_id: 1, ct });

    // Unknown request tag.
    assert!(matches!(
        decode_request(&[99u8], ctx),
        Err(CodecError::BadTag {
            context: "request",
            tag: 99
        })
    ));
    // Truncation at every prefix of the header region fails loudly.
    for cut in [1usize, 5, 12, 20, good.len() - 1] {
        assert!(
            decode_request(&good[..cut], ctx).is_err(),
            "cut at {cut} must not decode"
        );
    }
    // Trailing garbage after a complete message.
    let mut long = good.clone();
    long.push(0);
    assert!(matches!(
        decode_request(&long, ctx),
        Err(CodecError::TrailingBytes(1))
    ));
    // An out-of-range residue (~2^64 >= every modulus): the first c0
    // limb word lives after tag(1) + session(8) + level(1) + scale(8)
    // + poly header(3).
    let mut bad = good.clone();
    for b in bad.iter_mut().skip(21).take(8) {
        *b = 0xFF;
    }
    assert!(matches!(
        decode_request(&bad, ctx),
        Err(CodecError::BadValue("poly residue out of modulus range"))
    ));
    // A lying ciphertext level fails the chain check.
    let mut bad = good;
    bad[9] = 200;
    assert!(decode_request(&bad, ctx).is_err());
}

fn start_net_server(
    wl: &workload::Workload,
    sessions: Arc<SessionManager>,
    enc_batch: usize,
) -> NetServer {
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            queue_capacity: 16,
            enc_batch,
            ..Default::default()
        },
        wl.ctx.clone(),
        wl.server.clone(),
        sessions,
        None,
    );
    NetServer::start(
        NetServerConfig {
            addr: "127.0.0.1:0".to_string(),
            ..Default::default()
        },
        wl.ctx.clone(),
        wl.server.clone(),
        coord,
        enc_batch,
    )
    .expect("bind ephemeral port")
}

/// Full session over a real socket: model info → key registration →
/// encrypted submission → decrypted scores agreeing with the
/// plaintext slot model, plus the plaintext wire path.
#[test]
fn wire_session_register_submit_score() {
    let wl = workload::build(&small_spec());
    let net = start_net_server(&wl, Arc::new(SessionManager::new()), 1);
    let enc = Encoder::new(&wl.ctx);

    let mut client = NetClient::connect(net.local_addr(), wl.ctx.clone()).expect("connect");
    let info = client.model_info().expect("model info");
    assert_eq!(info.params_name, wl.params.name);
    assert_eq!(info.n as usize, wl.ctx.n());
    assert_eq!(info.features as usize, wl.server.model.plan.d);
    assert!(!info.rotations.is_empty());

    let rotations: Vec<usize> = info.rotations.iter().map(|&r| r as usize).collect();
    let mut kg = KeyGenerator::new(&wl.ctx, 31);
    let pk = kg.gen_public_key(&wl.ctx);
    let mut hrf_client = HrfClient::with_eval_keys(
        Encryptor::new(pk, 32),
        Decryptor::new(kg.secret_key()),
        kg.gen_relin_key(&wl.ctx),
        kg.gen_galois_keys(&wl.ctx, &rotations),
    );
    let keys = hrf_client.eval_keys().unwrap().clone();
    let sid = client.register_keys(&keys).expect("register");

    let x = &wl.data.x[3];
    let ct = hrf_client.encrypt_input(&wl.ctx, &enc, &wl.server.model, x);
    let outs = client.submit_encrypted(sid, &ct).expect("submit");
    let (scores, _) = hrf_client.decrypt_response(&wl.ctx, &enc, &outs);
    let expect = wl
        .server
        .model
        .forward_slots_plain(&reshuffle_and_pack(&wl.server.model, x));
    assert_eq!(scores.len(), expect.len());
    for (s, e) in scores.iter().zip(&expect) {
        assert!((s - e).abs() < 5e-3, "HE-over-wire vs plain: {scores:?} vs {expect:?}");
    }

    // Plaintext wire path agrees with the same slot model.
    let plain = client.submit_plain(x.clone()).expect("plain submit");
    for (s, e) in plain.iter().zip(&expect) {
        assert!((s - e).abs() < 5e-3, "plain-over-wire diverged: {plain:?} vs {expect:?}");
    }
    // A wrong-length vector is refused at the protocol layer — it
    // must not panic a worker.
    match client.submit_plain(vec![1.0, 2.0]) {
        Err(NetError::Protocol(_)) => {}
        other => panic!("expected protocol error, got {other:?}"),
    }

    drop(client);
    let report = net.shutdown();
    assert!(report.is_clean(), "unclean shutdown: {report:?}");
}

/// The eviction-recovery protocol over the wire: a budgeted key cache
/// evicts session A under pressure from B; A's next submit fails with
/// `KeysEvicted` (typed, over TCP), A re-registers under the same id,
/// and recovered scores are bit-identical. The recovering client
/// helper then handles a second eviction transparently.
#[test]
fn wire_eviction_reregister_recovers_identical_scores() {
    let wl = workload::build(&small_spec());
    let enc = Encoder::new(&wl.ctx);

    let mut kg_a = KeyGenerator::new(&wl.ctx, 41);
    let pk_a = kg_a.gen_public_key(&wl.ctx);
    let steps = wl.server.eval_key_requirements(1);
    let mut hrf_client = HrfClient::with_eval_keys(
        Encryptor::new(pk_a, 42),
        Decryptor::new(kg_a.secret_key()),
        kg_a.gen_relin_key(&wl.ctx),
        kg_a.gen_galois_keys(&wl.ctx, &steps),
    );
    let keys_a = hrf_client.eval_keys().unwrap().clone();
    let session_bytes = (keys_a.relin.key_bytes() + keys_a.galois.key_bytes()) as u64;
    let mut kg_b = KeyGenerator::new(&wl.ctx, 43);
    let _pk_b = kg_b.gen_public_key(&wl.ctx);
    let keys_b = EvalKeys {
        relin: kg_b.gen_relin_key(&wl.ctx),
        galois: kg_b.gen_galois_keys(&wl.ctx, &steps),
    };

    // Budget fits one session (plus slack), not two.
    let sessions = Arc::new(SessionManager::with_config(KeyCacheConfig {
        num_shards: 1,
        budget_bytes: session_bytes * 3 / 2,
    }));
    let net = start_net_server(&wl, sessions, 1);
    let metrics = net.metrics();

    let mut client = NetClient::connect(net.local_addr(), wl.ctx.clone()).expect("connect");
    let sid_a = client.register_keys(&keys_a).expect("register A");
    let x = &wl.data.x[5];
    let ct = hrf_client.encrypt_input(&wl.ctx, &enc, &wl.server.model, x);

    // Baseline before any eviction.
    let outs = client.submit_encrypted(sid_a, &ct).expect("baseline submit");
    let (scores_before, _) = hrf_client.decrypt_response(&wl.ctx, &enc, &outs);

    // Pressure: B's registration evicts A (global budget, over the
    // wire like everything else).
    let _sid_b = client.register_keys(&keys_b).expect("register B");
    match client.submit_encrypted(sid_a, &ct) {
        Err(NetError::Submit(SubmitError::KeysEvicted)) => {}
        other => panic!("expected KeysEvicted over the wire, got {other:?}"),
    }

    // Recover: same session id, same keys, bit-identical scores.
    assert!(client.reregister(sid_a, &keys_a).expect("reregister"));
    let outs = client.submit_encrypted(sid_a, &ct).expect("recovered submit");
    let (scores_after, _) = hrf_client.decrypt_response(&wl.ctx, &enc, &outs);
    assert_eq!(scores_before.len(), scores_after.len());
    for (b, a) in scores_before.iter().zip(&scores_after) {
        assert!(
            (b - a).abs() < 1e-9,
            "recovered session diverged: {scores_before:?} vs {scores_after:?}"
        );
    }

    // Evict A again; the recovering helper hides the round-trip.
    assert!(client.reregister(_sid_b, &keys_b).expect("reregister B"));
    let (outs, recoveries) = client
        .submit_encrypted_recovering(sid_a, &ct, &keys_a)
        .expect("recovering submit");
    assert!(recoveries >= 1, "helper should have re-registered at least once");
    let (scores_rec, _) = hrf_client.decrypt_response(&wl.ctx, &enc, &outs);
    for (b, a) in scores_before.iter().zip(&scores_rec) {
        assert!((b - a).abs() < 1e-9);
    }

    // Reconnecting does not lose the session: ids outlive connections.
    drop(client);
    let mut client = NetClient::connect(net.local_addr(), wl.ctx.clone()).expect("reconnect");
    let (outs, _) = client
        .submit_encrypted_recovering(sid_a, &ct, &keys_a)
        .expect("submit after reconnect");
    let (scores_reconn, _) = hrf_client.decrypt_response(&wl.ctx, &enc, &outs);
    for (b, a) in scores_before.iter().zip(&scores_reconn) {
        assert!((b - a).abs() < 1e-9);
    }

    let snap = metrics.snapshot();
    assert!(snap.rejected_keys_evicted >= 1);
    assert!(snap.keycache_evictions >= 2);
    assert!(snap.net_connections_accepted >= 2);

    drop(client);
    let report = net.shutdown();
    assert!(report.is_clean(), "unclean shutdown: {report:?}");
}

/// The wire-scrapable observability plane end-to-end: a client drives
/// encrypted + plain requests over a real socket, then explains them
/// from outside the process — `MetricsSnapshot` for counters and the
/// queue/service split, `TraceDump` for per-request span timelines
/// whose phases are complete and monotone.
#[test]
fn wire_metrics_snapshot_and_trace_dump() {
    let wl = workload::build(&small_spec());
    let net = start_net_server(&wl, Arc::new(SessionManager::new()), 1);
    let enc = Encoder::new(&wl.ctx);

    let mut client = NetClient::connect(net.local_addr(), wl.ctx.clone()).expect("connect");
    let info = client.model_info().expect("model info");
    let rotations: Vec<usize> = info.rotations.iter().map(|&r| r as usize).collect();
    let mut kg = KeyGenerator::new(&wl.ctx, 51);
    let pk = kg.gen_public_key(&wl.ctx);
    let mut hrf_client = HrfClient::with_eval_keys(
        Encryptor::new(pk, 52),
        Decryptor::new(kg.secret_key()),
        kg.gen_relin_key(&wl.ctx),
        kg.gen_galois_keys(&wl.ctx, &rotations),
    );
    let keys = hrf_client.eval_keys().unwrap().clone();
    let sid = client.register_keys(&keys).expect("register");

    let x = &wl.data.x[2];
    let ct = hrf_client.encrypt_input(&wl.ctx, &enc, &wl.server.model, x);
    client.submit_encrypted(sid, &ct).expect("encrypted submit");
    client.submit_plain(x.clone()).expect("plain submit");

    // The snapshot scraped over the wire matches what the requests did.
    let snap = client.metrics_snapshot().expect("metrics scrape");
    assert_eq!(snap.encrypted_completed, 1);
    assert_eq!(snap.plain_completed, 1);
    assert_eq!(snap.traces_recorded, 2, "both requests must be traced");
    assert_eq!(snap.traces_dropped, 0);
    assert!(snap.net_connections_accepted >= 1);
    assert!(snap.encrypted_mean > Duration::ZERO);
    assert!(snap.encrypted_p50 <= snap.encrypted_p99);
    // Queue + service spans the whole worker-side life of the request,
    // so neither side can exceed the end-to-end mean.
    assert!(snap.encrypted_queue_mean <= snap.encrypted_mean);
    assert!(snap.encrypted_service_mean <= snap.encrypted_mean);
    assert!(snap.encrypted_service_mean > Duration::ZERO);

    // The trace dump explains each request phase by phase.
    let traces = client.trace_dump().expect("trace dump");
    assert_eq!(traces.len(), 2);
    let enc_trace = traces
        .iter()
        .find(|t| t.kind == TraceKind::Encrypted)
        .expect("encrypted trace");
    let plain_trace = traces
        .iter()
        .find(|t| t.kind == TraceKind::Plain)
        .expect("plain trace");
    for t in [enc_trace, plain_trace] {
        // Every phase was stamped (both paths go through a batcher)…
        let offsets: Vec<u64> = TracePhase::ALL
            .iter()
            .map(|&p| {
                t.phase(p)
                    .unwrap_or_else(|| panic!("{:?} missing phase {p:?}", t.kind))
                    .as_micros() as u64
            })
            .collect();
        // …in order: wire accept ≤ decode ≤ admission ≤ flush ≤
        // execution ≤ response.
        assert!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "{:?} phases not monotone: {offsets:?}",
            t.kind
        );
        let (_fid, group) = t.flush.expect("flushed request carries a flush id");
        assert_eq!(group, 1, "single request per flush in this test");
        // The record's split agrees with the stamped phases.
        assert!(t.queue_time().is_some());
        assert!(t.service_time().is_some());
    }
    assert_ne!(
        enc_trace.flush.unwrap().0,
        plain_trace.flush.unwrap().0,
        "different flushes must not share a flush id"
    );

    drop(client);
    let report = net.shutdown();
    assert!(report.is_clean(), "unclean shutdown: {report:?}");
}
