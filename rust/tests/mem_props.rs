//! Properties of the memory plane (PR 9): the sharded slab pool
//! behind every `Scratch` handle, and the keycache disk-spill tier.
//!
//! * **Budget** — `resident_bytes <= budget` holds at every instant,
//!   including under concurrent checkout/return from many threads (a
//!   sampler thread watches the gauge while workers hammer the pool),
//!   and the gauge agrees exactly with a walk of the free lists once
//!   the pool is quiescent.
//! * **Reuse** — returning a buffer and re-requesting the same (or a
//!   smaller) size is a pool hit; capacity is recycled, not
//!   reallocated.
//! * **Spill round trip** — with the spill tier enabled, a
//!   budget-evicted session's keys reload transparently from disk:
//!   the full coordinator path serves the evicted session with ZERO
//!   `KeysEvicted` rejections and bit-identical scores.
//! * **Spill failure** — a corrupt spill file degrades to the plain
//!   `KeysEvicted`/re-register protocol (no panic, counted as
//!   corrupt); a zero-byte spill budget behaves exactly like the
//!   pre-spill cache.
//! * **Determinism** — `HrfServer::execute` stays bit-identical to
//!   serial across the `op_workers × ckks_workers` grid when every
//!   evaluator draws from one deliberately tiny shared slab pool.

use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{
    Ciphertext, CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator, Scratch,
};
use cryptotree::coordinator::{
    CacheState, Coordinator, CoordinatorConfig, SessionManager, SubmitError,
};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::{reshuffle_and_pack, HrfClient};
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::keycache::KeyCacheConfig;
use cryptotree::mem::SlabPool;
use cryptotree::nrf::activation::Activation;
use cryptotree::nrf::NeuralForest;
use cryptotree::rng::Xoshiro256pp;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

// ------------------------------------------------------------- slab

/// Sequential model check: random checkout/return traffic against a
/// small budget. After every single operation the gauge respects the
/// budget, and whenever all outstanding buffers are returned the
/// gauge equals an exact walk of the free lists.
#[test]
fn slab_budget_and_gauge_agree_under_random_traffic() {
    let mut rng = Xoshiro256pp::new(901);
    for case in 0..20 {
        let shards = 1 + rng.next_index(4);
        let budget = 8 * 64 * (1 + rng.next_below(64)); // multiples of one u64 row
        let pool = SlabPool::new(shards, budget);
        let mut held: Vec<(usize, Vec<u64>)> = Vec::new();
        for step in 0..400 {
            let home = rng.next_index(shards);
            if rng.next_f64() < 0.5 || held.is_empty() {
                let len = 1 + rng.next_index(96);
                let b = pool.take(home, len);
                assert_eq!(b.len(), len);
                assert!(b.iter().all(|&w| w == 0), "checkout must be zeroed");
                held.push((home, b));
            } else {
                let (home, b) = held.swap_remove(rng.next_index(held.len()));
                pool.put(home, b);
            }
            assert!(
                pool.resident_bytes() <= budget,
                "case {case} step {step}: resident {} > budget {budget}",
                pool.resident_bytes()
            );
        }
        for (home, b) in held.drain(..) {
            pool.put(home, b);
        }
        // Quiescent: the lock-free gauge and the exact walk agree.
        assert_eq!(pool.resident_bytes(), pool.audit_resident_bytes(), "case {case}");
        assert!(pool.resident_bytes() <= budget, "case {case}");
    }
}

/// Concurrency property: worker threads hammer one small pool through
/// `Scratch` handles while a sampler thread continuously asserts the
/// budget invariant. The CAS reserve in `put` means the gauge can
/// never overshoot even transiently.
#[test]
fn slab_budget_holds_at_every_instant_under_contention() {
    let budget = 64 * 1024u64;
    let pool = Arc::new(SlabPool::new(4, budget));
    let stop = Arc::new(AtomicBool::new(false));

    let sampler = {
        let pool = pool.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut peak = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let r = pool.resident_bytes();
                peak = peak.max(r);
                assert!(r <= budget, "sampler saw resident {r} > budget {budget}");
            }
            peak
        })
    };

    let workers: Vec<_> = (0..4)
        .map(|t| {
            let pool = pool.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256pp::new(9100 + t);
                let mut scratch = Scratch::in_pool(pool);
                let mut held: Vec<Vec<u64>> = Vec::new();
                for _ in 0..2000 {
                    if rng.next_f64() < 0.55 || held.is_empty() {
                        // Up to 2 KiB each: 4 threads × a few live
                        // buffers comfortably exceeds the budget, so
                        // trims and drops actually fire.
                        held.push(scratch.take(1 + rng.next_index(256)));
                    } else {
                        let b = held.swap_remove(rng.next_index(held.len()));
                        scratch.put(b);
                    }
                }
                for b in held.drain(..) {
                    scratch.put(b);
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let peak = sampler.join().expect("sampler must not have paniced");

    // Quiescent audit: no bytes were lost or double-counted by the
    // concurrent take/put/trim interleavings.
    assert_eq!(pool.resident_bytes(), pool.audit_resident_bytes());
    assert!(pool.resident_bytes() <= budget);
    assert!(peak <= budget);
    let s = pool.stats().snapshot();
    // The workload oversubscribes the budget, so the pool must have
    // actually exercised its pressure paths.
    assert!(s.hits + s.misses > 0);
    assert!(
        s.trims + s.dropped > 0,
        "budget pressure never fired: {s:?}"
    );
}

/// Size-class reuse: a returned buffer satisfies the next request of
/// the same length (exact class) and of a smaller length (first fit
/// picks the smallest sufficient class) without allocating.
#[test]
fn slab_recycles_capacity_across_requests() {
    let pool = SlabPool::new(1, 1 << 20);
    let b = pool.take(0, 512);
    let cap = b.capacity();
    pool.put(0, b);
    let hits_before = pool.stats().snapshot().hits;

    let b2 = pool.take(0, 512); // exact class
    assert_eq!(b2.capacity(), cap, "same-size request must reuse the slab");
    pool.put(0, b2);
    let b3 = pool.take(0, 100); // smaller request, first-fit
    assert_eq!(b3.capacity(), cap, "smaller request must reuse the slab");
    assert_eq!(b3.len(), 100);
    assert_eq!(pool.stats().snapshot().hits, hits_before + 2);
    pool.put(0, b3);
}

// ------------------------------------------------------ spill e2e

struct Workload {
    ctx: cryptotree::ckks::rns::ContextRef,
    enc: Encoder,
    server: Arc<HrfServer>,
}

/// Cheap ring (N=4096, depth 4) + tiny forest: the memory-plane
/// protocol is under test, not the numerics. Same shape as the
/// keycache property tests.
fn spill_workload(seed: u64) -> Workload {
    let params = Arc::new(CkksParams::build("mem-e2e-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let ds = adult::generate(400, seed);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 4,
            tree: cryptotree::forest::tree::TreeConfig {
                max_depth: 2,
                ..Default::default()
            },
            ..Default::default()
        },
        seed + 1,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: vec![0.0, 1.0],
        },
    );
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
    let server = Arc::new(HrfServer::new(model));
    Workload { ctx, enc, server }
}

fn make_client(w: &Workload, seed: u64) -> HrfClient {
    let mut kg = KeyGenerator::new(&w.ctx, seed);
    let pk = kg.gen_public_key(&w.ctx);
    let rlk = kg.gen_relin_key(&w.ctx);
    let gk = kg.gen_galois_keys(&w.ctx, &w.server.eval_key_requirements(1));
    HrfClient::with_eval_keys(
        Encryptor::new(pk, seed + 1),
        Decryptor::new(kg.secret_key()),
        rlk,
        gk,
    )
}

fn temp_spill_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cryptotree-mem-props-{}-{tag}", std::process::id()))
}

/// Tentpole acceptance: with the spill tier enabled through the
/// coordinator config, cache pressure demotes session A's keys to
/// disk and the next submission reloads them transparently —
/// bit-identical scores, zero `KeysEvicted` rejections end to end.
#[test]
fn spilled_session_serves_transparently_with_zero_evicted_errors() {
    let w = spill_workload(9200);
    let mut client_a = make_client(&w, 9301);
    let keys_a = client_a.eval_keys().expect("retained keys").clone();
    let session_bytes = (keys_a.relin.key_bytes() + keys_a.galois.key_bytes()) as u64;
    let mut client_b = make_client(&w, 9401);
    let keys_b = client_b.eval_keys().expect("retained keys").clone();

    // RAM budget fits one session; the spill tier takes the overflow.
    let sessions = Arc::new(SessionManager::with_config(KeyCacheConfig {
        num_shards: 4,
        budget_bytes: session_bytes * 3 / 2,
    }));
    let dir = temp_spill_dir("transparent");
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            queue_capacity: 16,
            spill_dir: Some(dir.clone()),
            spill_budget_bytes: 64 * 1024 * 1024,
            ..Default::default()
        },
        w.ctx.clone(),
        w.server.clone(),
        sessions.clone(),
        None,
    );
    assert!(sessions.spill_enabled());

    let sid_a = sessions.register_keys(&keys_a);
    let mut rng = Xoshiro256pp::new(9501);
    let x: Vec<f64> = (0..w.server.model.plan.d)
        .map(|_| rng.next_f64() * 2.0 - 1.0)
        .collect();
    let ct = client_a.encrypt_input(&w.ctx, &w.enc, &w.server.model, &x);

    // Baseline before any eviction.
    let rx = coord.submit_encrypted(sid_a, ct.clone()).expect("submit");
    let outs = rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    let (scores_before, _) = client_a.decrypt_response(&w.ctx, &w.enc, &outs);

    // Pressure: registering B evicts A — but A's keys spill to disk
    // instead of vanishing.
    let _sid_b = sessions.register_keys(&keys_b);
    assert!(sessions.resident_bytes() <= session_bytes * 3 / 2);
    assert!(
        matches!(sessions.peek(sid_a), CacheState::Spilled),
        "A's keys should be on disk, not gone"
    );
    assert!(sessions.spilled_len() >= 1);
    assert!(sessions.spilled_bytes() > 0);

    // The same submission that returns KeysEvicted without the spill
    // tier now succeeds: lookup promotes A back from disk.
    let rx = coord
        .submit_encrypted(sid_a, ct.clone())
        .expect("spilled session must submit without re-registration");
    let outs = rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    let (scores_after, _) = client_a.decrypt_response(&w.ctx, &w.enc, &outs);

    assert_eq!(scores_before.len(), scores_after.len());
    for (b, a) in scores_before.iter().zip(&scores_after) {
        assert!(
            (b - a).abs() < 1e-9,
            "reloaded keys diverged: {scores_before:?} vs {scores_after:?}"
        );
    }
    // And both agree with the plaintext slot model.
    let expect = w
        .server
        .model
        .forward_slots_plain(&reshuffle_and_pack(&w.server.model, &x));
    for (s, e) in scores_after.iter().zip(&expect) {
        assert!((s - e).abs() < 5e-3, "HE vs plain: {scores_after:?} vs {expect:?}");
    }

    let snap = coord.metrics.snapshot();
    assert_eq!(
        snap.rejected_keys_evicted, 0,
        "spill tier must absorb the eviction"
    );
    assert!(snap.keycache_spill_hits >= 1, "reload must be counted");
    assert_eq!(snap.keycache_spill_corrupt, 0);
    assert!(snap.keycache_evictions >= 1, "RAM eviction still happened");
    coord.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// A corrupt spill file is a miss, not a panic: the session degrades
/// to the plain `KeysEvicted` → re-register protocol and the file is
/// quarantined (deleted + counted).
#[test]
fn corrupt_spill_file_degrades_to_reregister_protocol() {
    let w = spill_workload(9600);
    let mut client_a = make_client(&w, 9701);
    let keys_a = client_a.eval_keys().expect("retained keys").clone();
    let session_bytes = (keys_a.relin.key_bytes() + keys_a.galois.key_bytes()) as u64;
    let mut client_b = make_client(&w, 9801);
    let keys_b = client_b.eval_keys().expect("retained keys").clone();

    let sessions = Arc::new(SessionManager::with_config(KeyCacheConfig {
        num_shards: 4,
        budget_bytes: session_bytes * 3 / 2,
    }));
    let dir = temp_spill_dir("corrupt");
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            queue_capacity: 16,
            spill_dir: Some(dir.clone()),
            spill_budget_bytes: 64 * 1024 * 1024,
            ..Default::default()
        },
        w.ctx.clone(),
        w.server.clone(),
        sessions.clone(),
        None,
    );

    let sid_a = sessions.register_keys(&keys_a);
    let _sid_b = sessions.register_keys(&keys_b); // evicts + spills A
    assert!(matches!(sessions.peek(sid_a), CacheState::Spilled));

    // Sabotage the spill file (truncation / bit rot / partial disk).
    let spill_file = dir.join(format!("{sid_a}.spill"));
    assert!(spill_file.exists(), "expected {} on disk", spill_file.display());
    std::fs::write(&spill_file, b"not a key-switching key").unwrap();

    let mut rng = Xoshiro256pp::new(9901);
    let x: Vec<f64> = (0..w.server.model.plan.d)
        .map(|_| rng.next_f64() * 2.0 - 1.0)
        .collect();
    let ct = client_a.encrypt_input(&w.ctx, &w.enc, &w.server.model, &x);

    // The reload fails cleanly: typed error, not a panic, and the
    // poisoned file is removed so it cannot fail again.
    match coord.submit_encrypted(sid_a, ct.clone()) {
        Err(SubmitError::KeysEvicted) => {}
        Ok(_) => panic!("corrupt spill file must not serve"),
        Err(other) => panic!("expected KeysEvicted, got {other:?}"),
    }
    assert!(!spill_file.exists(), "corrupt file must be quarantined");

    // Standard recovery still works.
    assert!(sessions.reregister_keys(sid_a, &keys_a));
    let rx = coord
        .submit_encrypted(sid_a, ct)
        .expect("submit after re-registration");
    let outs = rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    let (scores, _) = client_a.decrypt_response(&w.ctx, &w.enc, &outs);
    let expect = w
        .server
        .model
        .forward_slots_plain(&reshuffle_and_pack(&w.server.model, &x));
    for (s, e) in scores.iter().zip(&expect) {
        assert!((s - e).abs() < 5e-3, "HE vs plain: {scores:?} vs {expect:?}");
    }

    let snap = coord.metrics.snapshot();
    assert_eq!(snap.keycache_spill_corrupt, 1);
    assert!(snap.rejected_keys_evicted >= 1);
    coord.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// With a zero-byte spill budget every spill write is refused, so the
/// cache behaves exactly like the pre-spill build: `Evicted`, typed
/// rejection, recovery via re-registration.
#[test]
fn zero_spill_budget_behaves_like_plain_eviction() {
    let w = spill_workload(10_000);
    let mut client_a = make_client(&w, 10_101);
    let keys_a = client_a.eval_keys().expect("retained keys").clone();
    let session_bytes = (keys_a.relin.key_bytes() + keys_a.galois.key_bytes()) as u64;
    let mut client_b = make_client(&w, 10_201);
    let keys_b = client_b.eval_keys().expect("retained keys").clone();

    let sessions = Arc::new(SessionManager::with_config(KeyCacheConfig {
        num_shards: 4,
        budget_bytes: session_bytes * 3 / 2,
    }));
    let dir = temp_spill_dir("budget0");
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            queue_capacity: 16,
            spill_dir: Some(dir.clone()),
            spill_budget_bytes: 0, // tier present but can hold nothing
            ..Default::default()
        },
        w.ctx.clone(),
        w.server.clone(),
        sessions.clone(),
        None,
    );

    let sid_a = sessions.register_keys(&keys_a);
    let _sid_b = sessions.register_keys(&keys_b);
    // Too big for the (empty) spill budget: truly evicted.
    assert!(matches!(sessions.peek(sid_a), CacheState::Evicted));
    assert_eq!(sessions.spilled_len(), 0);

    let mut rng = Xoshiro256pp::new(10_301);
    let x: Vec<f64> = (0..w.server.model.plan.d)
        .map(|_| rng.next_f64() * 2.0 - 1.0)
        .collect();
    let ct = client_a.encrypt_input(&w.ctx, &w.enc, &w.server.model, &x);
    match coord.submit_encrypted(sid_a, ct.clone()) {
        Err(SubmitError::KeysEvicted) => {}
        other => panic!("expected KeysEvicted, got {:?}", other.map(|_| ())),
    }
    assert!(sessions.reregister_keys(sid_a, &keys_a));
    let rx = coord.submit_encrypted(sid_a, ct).expect("submit after re-registration");
    rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.keycache_spill_hits, 0);
    assert!(snap.rejected_keys_evicted >= 1);
    coord.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

// -------------------------------------------- shared-pool determinism

fn ct_bits_equal(a: &Ciphertext, b: &Ciphertext) -> bool {
    a.level == b.level
        && a.scale.to_bits() == b.scale.to_bits()
        && a.c0.data() == b.c0.data()
        && a.c1.data() == b.c1.data()
}

/// `HrfServer::execute` over the `op_workers × ckks_workers` grid with
/// every evaluator drawing from ONE deliberately tiny shared slab
/// pool: recycling, stealing, trimming and dropping under pressure
/// must never change a single ciphertext bit vs the serial baseline.
#[test]
fn dag_grid_bit_identical_with_shared_tiny_pool() {
    let w = spill_workload(10_400);
    let plan = w.server.model.plan;
    let mut kg = KeyGenerator::new(&w.ctx, 10_501);
    let pk = kg.gen_public_key(&w.ctx);
    let rlk = kg.gen_relin_key(&w.ctx);
    let b = plan.groups.min(2);
    let gk = kg.gen_galois_keys(&w.ctx, &plan.rotations_needed_batched(b));
    let mut client = HrfClient::new(Encryptor::new(pk, 10_502), Decryptor::new(kg.secret_key()));
    let mut rng = Xoshiro256pp::new(10_503);

    let xs: Vec<Vec<f64>> = (0..b)
        .map(|_| (0..plan.d).map(|_| rng.next_f64()).collect())
        .collect();
    let cts: Vec<Ciphertext> = xs
        .iter()
        .map(|x| client.encrypt_input(&w.ctx, &w.enc, &w.server.model, x))
        .collect();

    // ~1 MiB: far below one limb-buffer working set at N=4096, so the
    // pool trims and drops constantly while the grid runs.
    let pool = Arc::new(SlabPool::new(4, 1 << 20));

    w.server.set_op_workers(1);
    w.ctx.set_workers(1);
    let mut ev = Evaluator::with_scratch(w.ctx.clone(), Scratch::in_pool(pool.clone()));
    let base = w
        .server
        .execute(&mut ev, &w.enc, &EncRequest::group(&cts), &rlk, &gk)
        .into_class_scores();

    for ow in [1usize, 2, 4] {
        for cw in [1usize, 2] {
            if ow == 1 && cw == 1 {
                continue; // the baseline itself
            }
            w.server.set_op_workers(ow);
            w.ctx.set_workers(cw);
            let mut ev = Evaluator::with_scratch(w.ctx.clone(), Scratch::in_pool(pool.clone()));
            let ex = w
                .server
                .execute(&mut ev, &w.enc, &EncRequest::group(&cts), &rlk, &gk);
            for (got, want) in ex.into_class_scores().iter().zip(&base) {
                assert!(
                    ct_bits_equal(got, want),
                    "ow={ow} cw={cw}: shared-pool run deviates from serial"
                );
            }
            assert!(
                pool.resident_bytes() <= pool.budget_bytes(),
                "ow={ow} cw={cw}: pool over budget"
            );
        }
    }
    w.server.set_op_workers(1);
    w.ctx.set_workers(1);
    let s = pool.stats().snapshot();
    assert!(s.hits > 0, "the grid must actually recycle buffers: {s:?}");
}
