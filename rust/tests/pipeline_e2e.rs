//! End-to-end integration: train → NRF → fine-tune → pack → encrypt →
//! coordinator → decrypt, with HRF/NRF agreement (E2/E3 at test scale).

use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager, SubmitError};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::{finetune_last_layer, FinetuneConfig, NeuralForest};
use std::sync::Arc;

struct Pipeline {
    ctx: cryptotree::ckks::rns::ContextRef,
    enc: Encoder,
    client: HrfClient,
    server: Arc<HrfServer>,
    sessions: Arc<SessionManager>,
    sid: u64,
    nf: NeuralForest,
    valid: cryptotree::data::Dataset,
}

fn build(n_trees: usize, seed: u64) -> Pipeline {
    let ds = adult::generate(3_000, seed);
    let (train, valid) = ds.split(0.8, seed + 1);
    let rf = RandomForest::fit(
        &train,
        &RandomForestConfig {
            n_trees,
            ..Default::default()
        },
        seed + 2,
    );
    let coeffs = chebyshev_fit_tanh(3.0, 4);
    let mut nf = NeuralForest::from_forest(&rf, Activation::Poly { coeffs });
    finetune_last_layer(
        &mut nf,
        &train,
        &FinetuneConfig {
            epochs: 10,
            ..Default::default()
        },
        seed + 3,
    );

    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
    let plan = model.plan;

    let mut kg = KeyGenerator::new(&ctx, seed + 4);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
    let client = HrfClient::new(
        Encryptor::new(pk, seed + 5),
        Decryptor::new(kg.secret_key()),
    );
    let sessions = Arc::new(SessionManager::new());
    let sid = sessions.register(rlk, gk);
    Pipeline {
        ctx,
        enc,
        client,
        server: Arc::new(HrfServer::new(model)),
        sessions,
        sid,
        nf,
        valid,
    }
}

#[test]
fn encrypted_pipeline_agrees_with_nrf() {
    let mut p = build(6, 101);
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            ..Default::default()
        },
        p.ctx.clone(),
        p.server.clone(),
        p.sessions.clone(),
        None,
    );
    let n_eval = 6;
    let mut agree = 0;
    for i in 0..n_eval {
        let x = &p.valid.x[i];
        let ct = p.client.encrypt_input(&p.ctx, &p.enc, &p.server.model, x);
        let rx = coord.submit_encrypted(p.sid, ct).expect("submit");
        let outs = rx.recv().unwrap().expect("eval ok");
        let (scores, pred) = p.client.decrypt_response(&p.ctx, &p.enc, &outs);
        let nrf_scores = p.nf.forward(x);
        // Scores must match the plaintext NRF closely (CKKS noise only).
        for (s, e) in scores.iter().zip(&nrf_scores) {
            assert!(
                (s - e).abs() < 5e-3,
                "sample {i}: encrypted {scores:?} vs NRF {nrf_scores:?}"
            );
        }
        if pred == p.nf.predict(x) {
            agree += 1;
        }
    }
    assert_eq!(agree, n_eval, "argmax disagreement under small noise");
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.encrypted_completed, n_eval as u64);
    coord.shutdown();
}

#[test]
fn plain_path_matches_nrf_and_batches() {
    let p = build(6, 202);
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_delay: std::time::Duration::from_millis(20),
            // This test asserts aggregation under a burst, so pin the
            // idle grace to the full window (adaptive idle-flush off).
            idle_flush: std::time::Duration::from_millis(20),
            ..Default::default()
        },
        p.ctx.clone(),
        p.server.clone(),
        p.sessions.clone(),
        None, // Rust slot-math fallback; PJRT path covered in runtime_artifact.rs
    );
    // Burst of 8 → expect ≥2 flushed batches, every response correct.
    let rxs: Vec<_> = (0..8)
        .map(|i| coord.submit_plain(p.valid.x[i].clone()).expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let scores = rx.recv().unwrap().expect("plain eval");
        let expect = {
            let slots =
                cryptotree::hrf::client::reshuffle_and_pack(&p.server.model, &p.valid.x[i]);
            p.server.model.forward_slots_plain(&slots)
        };
        for (g, e) in scores.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-9, "plain path mismatch at {i}");
        }
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.plain_completed, 8);
    assert!(snap.batches_flushed >= 2);
    assert!(snap.mean_batch_fill > 1.0, "batching never aggregated");
    coord.shutdown();
}

#[test]
fn unknown_session_is_rejected() {
    let mut p = build(4, 303);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        p.ctx.clone(),
        p.server.clone(),
        p.sessions.clone(),
        None,
    );
    let ct = p
        .client
        .encrypt_input(&p.ctx, &p.enc, &p.server.model, &p.valid.x[0]);
    match coord.submit_encrypted(9999, ct) {
        Err(SubmitError::NoSession) => {}
        other => panic!("expected NoSession, got {other:?}"),
    }
    assert_eq!(coord.metrics.snapshot().rejected_no_session, 1);
    coord.shutdown();
}

#[test]
fn session_isolation_two_clients() {
    // Two clients, separate keys: each decrypts only its own result.
    let mut p = build(4, 404);
    // Second client with fresh keys on the same context/model.
    let mut kg2 = KeyGenerator::new(&p.ctx, 909);
    let pk2 = kg2.gen_public_key(&p.ctx);
    let rlk2 = kg2.gen_relin_key(&p.ctx);
    let gk2 = kg2.gen_galois_keys(&p.ctx, &p.server.model.plan.rotations_needed());
    let mut client2 = HrfClient::new(
        Encryptor::new(pk2, 910),
        Decryptor::new(kg2.secret_key()),
    );
    let sid2 = p.sessions.register(rlk2, gk2);

    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        p.ctx.clone(),
        p.server.clone(),
        p.sessions.clone(),
        None,
    );
    let x = &p.valid.x[0];
    let ct1 = p.client.encrypt_input(&p.ctx, &p.enc, &p.server.model, x);
    let ct2 = client2.encrypt_input(&p.ctx, &p.enc, &p.server.model, x);
    let r1 = coord.submit_encrypted(p.sid, ct1).unwrap();
    let r2 = coord.submit_encrypted(sid2, ct2).unwrap();
    let o1 = r1.recv().unwrap().unwrap();
    let o2 = r2.recv().unwrap().unwrap();
    let (s1, _) = p.client.decrypt_response(&p.ctx, &p.enc, &o1);
    let (s2, _) = client2.decrypt_response(&p.ctx, &p.enc, &o2);
    let expect = {
        let slots = cryptotree::hrf::client::reshuffle_and_pack(&p.server.model, x);
        p.server.model.forward_slots_plain(&slots)
    };
    for (got, e) in [&s1, &s2].iter().zip([&expect, &expect]) {
        for (g, e) in got.iter().zip(e) {
            assert!((g - e).abs() < 5e-3, "client result wrong");
        }
    }
    // Cross-decryption must NOT work: decrypting client2's result with
    // client1's key yields garbage.
    let (cross, _) = p.client.decrypt_response(&p.ctx, &p.enc, &o2);
    let cross_err: f64 = cross
        .iter()
        .zip(&expect)
        .map(|(g, e)| (g - e).abs())
        .fold(0.0, f64::max);
    assert!(
        cross_err > 1e3,
        "cross-session decryption produced plausible values ({cross_err})"
    );
    coord.shutdown();
}
