//! Cross-backend parity for the schedule engine (ISSUE 4):
//!
//! One compiled [`HrfSchedule`] executed through the generic engine
//! must mean the same thing on every backend, with and without the
//! fusion pass, for B ∈ {1, 2, max}:
//!
//! * **CkksBackend** (via `HrfServer::execute`) — decrypted scores
//!   match the plaintext oracle; pass-optimized execution is
//!   **bit-identical** to both the unoptimized execution and the
//!   retained hand-written `eval_reference` path.
//! * **SlotBackend** — f32 scores from the same schedules (raw and
//!   fused) are bit-identical to each other and agree with the
//!   decrypted CKKS scores and the f64 slot oracle.
//! * **CountingBackend** — dry-run predictions equal the CKKS
//!   backend's measured counters op for op, including the fused
//!   `mul_plain_rescale` accounting.

use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{Ciphertext, CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::hrf::client::{reshuffle_and_pack, HrfClient};
use cryptotree::hrf::{EncRequest, HrfModel, HrfServer};
use cryptotree::nrf::{Activation, NeuralForest, NeuralTree};
use cryptotree::rng::Xoshiro256pp;
use cryptotree::runtime::{PassPipeline, SlotModelParams, SlotShape};
use std::sync::Arc;

fn synth_forest(k: usize, l: usize, c: usize, d: usize, rng: &mut Xoshiro256pp) -> NeuralForest {
    let trees = (0..l)
        .map(|_| NeuralTree {
            tau: (0..k - 1).map(|_| rng.next_index(d)).collect(),
            t: (0..k - 1).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            v: (0..k)
                .map(|_| (0..k - 1).map(|_| rng.uniform(-0.25, 0.25)).collect())
                .collect(),
            b: (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            w: (0..c)
                .map(|_| (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect())
                .collect(),
            beta: (0..c).map(|_| rng.uniform(-0.2, 0.2)).collect(),
            real_leaves: k,
            n_classes: c,
        })
        .collect();
    NeuralForest {
        trees,
        alphas: (0..l).map(|_| rng.uniform(0.1, 1.0)).collect(),
        k,
        n_classes: c,
        activation: Activation::Poly {
            coeffs: vec![0.0, 1.0], // identity: fits the depth-4 ring
        },
    }
}

fn ct_bits_equal(a: &Ciphertext, b: &Ciphertext) -> bool {
    a.level == b.level
        && a.scale.to_bits() == b.scale.to_bits()
        && a.c0.data() == b.c0.data()
        && a.c1.data() == b.c1.data()
}

#[test]
fn cross_backend_parity_with_and_without_fusion() {
    let mut rng = Xoshiro256pp::new(9001);
    let d = 8;
    let nf = synth_forest(4, 4, 2, d, &mut rng);
    let params = Arc::new(CkksParams::build("engine-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;

    let mut kg = KeyGenerator::new(&ctx, 9002);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(8.min(plan.groups)));
    let mut client = HrfClient::new(Encryptor::new(pk, 9003), Decryptor::new(kg.secret_key()));

    // Two servers over the same model: standard pipeline vs no passes.
    let server_fused = HrfServer::new(hm.clone());
    let server_raw = HrfServer::with_passes(hm.clone(), PassPipeline::empty());

    // f32 slot-model parameters for the SlotBackend runs.
    let shape = SlotShape {
        s: plan.slots,
        k: plan.k,
        c: plan.c,
        m: hm.act_coeffs.len(),
        b: 8,
    };
    let slot_params = SlotModelParams::from_hrf(&hm, shape).unwrap();

    let b_max = plan.groups.min(5);
    for b in [1usize, 2, b_max] {
        let xs: Vec<Vec<f64>> = (0..b)
            .map(|_| (0..d).map(|_| rng.uniform(0.0, 1.0)).collect())
            .collect();
        let cts: Vec<Ciphertext> = xs
            .iter()
            .map(|x| client.encrypt_input(&ctx, &enc, &server_raw.model, x))
            .collect();

        // --- CKKS: fused vs raw vs hand-written reference ----------
        let mut ev_f = Evaluator::new(ctx.clone());
        let ex_f = server_fused.execute(&mut ev_f, &enc, &EncRequest::group(&cts), &rlk, &gk);
        let counts_f = ex_f.counts;
        let outs_f = ex_f.into_class_scores();

        let mut ev_r = Evaluator::new(ctx.clone());
        let ex_r = server_raw.execute(&mut ev_r, &enc, &EncRequest::group(&cts), &rlk, &gk);
        let counts_r = ex_r.counts;
        let outs_r = ex_r.into_class_scores();

        let mut ev_ref = Evaluator::new(ctx.clone());
        let packed = if b == 1 {
            cts[0].clone()
        } else {
            server_raw.pack_group(&mut ev_ref, &cts, &gk)
        };
        let (reference, _) = server_raw.eval_reference(&mut ev_ref, &enc, &packed, &rlk, &gk);

        assert_eq!(outs_f.len(), plan.c);
        for ((f, r), refr) in outs_f.iter().zip(&outs_r).zip(&reference) {
            assert!(ct_bits_equal(f, r), "B={b}: fusion changed ciphertext bits");
            assert!(
                ct_bits_equal(f, refr),
                "B={b}: engine deviates from hand-written reference bits"
            );
        }

        // --- Counting backend vs measured CKKS counters ------------
        assert_eq!(counts_f, server_fused.predicted_counts(b, true), "B={b} fused");
        assert_eq!(counts_r, server_raw.predicted_counts(b, true), "B={b} raw");
        let tf = counts_f.total();
        let tr = counts_r.total();
        assert_eq!(tf.fused_mul_rescale, plan.c as u64, "B={b}: C fused pairs");
        assert_eq!(tr.fused_mul_rescale, 0, "B={b}: raw server must not fuse");
        assert_eq!(tr.mul_plain - tf.mul_plain, plan.c as u64);
        assert_eq!(tr.rescale - tf.rescale, plan.c as u64);
        assert_eq!(tf.multiplications(), tr.multiplications());
        assert_eq!(tf.rescales(), tr.rescales());
        assert_eq!(tf.rotate, tr.rotate, "B={b}: fusion must not touch rotations");

        // --- SlotBackend: raw vs fused schedules, vs CKKS, vs oracle -
        let singles: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| {
                reshuffle_and_pack(&server_raw.model, x)
                    .iter()
                    .map(|&v| v as f32)
                    .collect()
            })
            .collect();
        let rows_raw = slot_params.run_schedule(&server_raw.schedule(b, true), &singles);
        let rows_fused = slot_params.run_schedule(&server_fused.schedule(b, true), &singles);
        assert_eq!(rows_raw, rows_fused, "B={b}: fusion changed f32 results");

        for (g, x) in xs.iter().enumerate() {
            let (he_scores, _) =
                client.decrypt_scores_at(&ctx, &enc, &outs_f, plan.score_slot(g));
            let oracle = server_raw
                .model
                .forward_slots_plain(&reshuffle_and_pack(&server_raw.model, x));
            for ((he, f32s), oc) in he_scores.iter().zip(&rows_raw[g]).zip(&oracle) {
                assert!(
                    (he - oc).abs() < 5e-3,
                    "B={b} sample {g}: CKKS {he} vs oracle {oc}"
                );
                assert!(
                    (*f32s as f64 - oc).abs() < 1e-3,
                    "B={b} sample {g}: slot backend {f32s} vs oracle {oc}"
                );
                assert!(
                    (he - *f32s as f64).abs() < 5e-3,
                    "B={b} sample {g}: CKKS {he} vs slot backend {f32s}"
                );
            }
        }
    }
}

/// The deprecated wrapper trio must stay exact shims over `execute`.
#[test]
#[allow(deprecated)]
fn deprecated_wrappers_match_execute() {
    let mut rng = Xoshiro256pp::new(9101);
    let d = 8;
    let nf = synth_forest(4, 3, 2, d, &mut rng);
    let params = Arc::new(CkksParams::build("wrap-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;
    let mut kg = KeyGenerator::new(&ctx, 9102);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(3.min(plan.groups)));
    let mut client = HrfClient::new(Encryptor::new(pk, 9103), Decryptor::new(kg.secret_key()));
    let server = HrfServer::new(hm);

    let b = plan.groups.min(3);
    let xs: Vec<Vec<f64>> = (0..b)
        .map(|_| (0..d).map(|_| rng.uniform(0.0, 1.0)).collect())
        .collect();
    let cts: Vec<Ciphertext> = xs
        .iter()
        .map(|x| client.encrypt_input(&ctx, &enc, &server.model, x))
        .collect();

    let mut ev_a = Evaluator::new(ctx.clone());
    let (w_single, _) = server.eval(&mut ev_a, &enc, &cts[0], &rlk, &gk);
    let mut ev_b = Evaluator::new(ctx.clone());
    let e_single = server
        .execute(&mut ev_b, &enc, &EncRequest::single(&cts[0]), &rlk, &gk)
        .into_class_scores();
    for (w, e) in w_single.iter().zip(&e_single) {
        assert!(ct_bits_equal(w, e), "eval wrapper deviates from execute");
    }

    let mut ev_c = Evaluator::new(ctx.clone());
    let (w_folded, _) = server.eval_batch_folded(&mut ev_c, &enc, &cts, &rlk, &gk);
    let mut ev_d = Evaluator::new(ctx.clone());
    let e_folded = server
        .execute(&mut ev_d, &enc, &EncRequest::group(&cts), &rlk, &gk)
        .into_class_scores();
    for (w, e) in w_folded.iter().zip(&e_folded) {
        assert!(
            ct_bits_equal(w, e),
            "eval_batch_folded wrapper deviates from execute"
        );
    }

    // EncExecution's per-sample accessors agree with the batch shape
    // and clone the shared folded group bit-for-bit.
    let mut ev_g = Evaluator::new(ctx.clone());
    let ex = server.execute(&mut ev_g, &enc, &EncRequest::group(&cts), &rlk, &gk);
    assert_eq!(ex.n_samples(), b);
    for g in 0..b {
        assert_eq!(ex.slot(g), plan.score_slot(g));
        let r = ex.response(g);
        assert_eq!(r.slot, plan.score_slot(g));
        for (a, e) in r.scores.iter().zip(&e_folded) {
            assert!(ct_bits_equal(a, e), "response({g}) deviates from class scores");
        }
    }

    let mut ev_e = Evaluator::new(ctx.clone());
    let (w_batch, _) = server.eval_batch(&mut ev_e, &enc, &cts, &rlk, &gk);
    let mut ev_f = Evaluator::new(ctx.clone());
    let e_batch = server
        .execute(&mut ev_f, &enc, &EncRequest::group_slot0(&cts), &rlk, &gk)
        .into_per_sample();
    assert_eq!(w_batch.len(), e_batch.len());
    for (ws, es) in w_batch.iter().zip(&e_batch) {
        for (w, e) in ws.iter().zip(es) {
            assert!(ct_bits_equal(w, e), "eval_batch wrapper deviates from execute");
        }
    }
}
