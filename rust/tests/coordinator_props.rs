//! Coordinator behavioural properties: backpressure, shutdown
//! discipline, and fairness of the least-loaded router.

use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager, SubmitError};
use cryptotree::data::adult;
use cryptotree::forest::{RandomForest, RandomForestConfig};
use cryptotree::hrf::client::HrfClient;
use cryptotree::hrf::{HrfModel, HrfServer};
use cryptotree::nrf::activation::{chebyshev_fit_tanh, Activation};
use cryptotree::nrf::NeuralForest;
use std::sync::Arc;
use std::time::Duration;

fn small_world() -> (
    cryptotree::ckks::rns::ContextRef,
    Encoder,
    HrfClient,
    Arc<HrfServer>,
    Arc<SessionManager>,
    u64,
    cryptotree::data::Dataset,
) {
    // The coordinator's queueing behaviour is what's under test here,
    // so keep CKKS cheap: tiny ring (N=4096, depth 4, test-grade
    // security) and a degree-1 activation — still exercising the full
    // op pipeline (1 level per activation + 2 plaintext muls = 4).
    let ds = adult::generate(600, 616);
    let rf = RandomForest::fit(
        &ds,
        &RandomForestConfig {
            n_trees: 4,
            tree: cryptotree::forest::tree::TreeConfig {
                max_depth: 2,
                ..Default::default()
            },
            ..Default::default()
        },
        617,
    );
    let nf = NeuralForest::from_forest(
        &rf,
        Activation::Poly {
            coeffs: vec![0.0, 1.0], // identity: depth-friendly
        },
    );
    let params = std::sync::Arc::new(CkksParams::build(
        "coord-test-n4096-d4",
        4096,
        60,
        40,
        4,
        3.2,
    ));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let model = HrfModel::from_neural_forest(&nf, ds.n_features(), params.slots()).unwrap();
    let plan = model.plan;
    let mut kg = KeyGenerator::new(&ctx, 618);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
    let client = HrfClient::new(Encryptor::new(pk, 619), Decryptor::new(kg.secret_key()));
    let sessions = Arc::new(SessionManager::new());
    let sid = sessions.register(rlk, gk);
    (
        ctx,
        enc,
        client,
        Arc::new(HrfServer::new(model)),
        sessions,
        sid,
        ds,
    )
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let (ctx, enc, mut client, server, sessions, sid, ds) = small_world();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            queue_capacity: 2, // tiny ingress
            ..Default::default()
        },
        ctx.clone(),
        server.clone(),
        sessions,
        None,
    );
    // Flood with encrypted requests; the single worker can't keep up,
    // so some submissions must hit Busy.
    let mut accepted = Vec::new();
    let mut busy = 0usize;
    for i in 0..40 {
        let ct = client.encrypt_input(&ctx, &enc, &server.model, &ds.x[i % ds.len()]);
        match coord.submit_encrypted(sid, ct) {
            Ok(rx) => accepted.push(rx),
            Err(SubmitError::Busy) => busy += 1,
            Err(e) => panic!("unexpected error {e:?}"),
        }
    }
    assert!(busy > 0, "backpressure never triggered");
    assert_eq!(
        coord.metrics.snapshot().rejected_backpressure,
        busy as u64
    );
    // Every accepted request still completes.
    for rx in accepted {
        let outs = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(outs.is_ok());
    }
    coord.shutdown();
}

#[test]
fn all_workers_receive_work() {
    let (ctx, enc, mut client, server, sessions, sid, ds) = small_world();
    let workers = 3;
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers,
            queue_capacity: 128,
            ..Default::default()
        },
        ctx.clone(),
        server.clone(),
        sessions,
        None,
    );
    let rxs: Vec<_> = (0..12)
        .map(|i| {
            let ct = client.encrypt_input(&ctx, &enc, &server.model, &ds.x[i]);
            coord.submit_encrypted(sid, ct).expect("queue has room")
        })
        .collect();
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
    }
    assert_eq!(coord.metrics.snapshot().encrypted_completed, 12);
    coord.shutdown();
}

#[test]
fn shutdown_is_clean_and_rejects_afterwards() {
    let (ctx, _enc, _client, server, sessions, _sid, ds) = small_world();
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        ctx.clone(),
        server.clone(),
        sessions.clone(),
        None,
    );
    let rx = coord.submit_plain(ds.x[0].clone()).unwrap();
    assert!(rx.recv_timeout(Duration::from_secs(60)).unwrap().is_ok());
    coord.shutdown(); // must join all threads without hanging

    // A fresh coordinator on the same resources still works (no
    // poisoned shared state).
    let coord2 = Coordinator::start(
        CoordinatorConfig::default(),
        ctx,
        server,
        sessions,
        None,
    );
    let rx2 = coord2.submit_plain(ds.x[1].clone()).unwrap();
    assert!(rx2.recv_timeout(Duration::from_secs(60)).unwrap().is_ok());
    coord2.shutdown();
}

#[test]
fn mixed_traffic_completes() {
    let (ctx, enc, mut client, server, sessions, sid, ds) = small_world();
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 2,
            queue_capacity: 64,
            max_batch: 4,
            batch_delay: Duration::from_millis(2),
            ..Default::default()
        },
        ctx.clone(),
        server.clone(),
        sessions,
        None,
    );
    let mut enc_rxs = Vec::new();
    let mut plain_rxs = Vec::new();
    for i in 0..6 {
        let ct = client.encrypt_input(&ctx, &enc, &server.model, &ds.x[i]);
        enc_rxs.push(coord.submit_encrypted(sid, ct).unwrap());
        plain_rxs.push(coord.submit_plain(ds.x[i].clone()).unwrap());
    }
    for rx in enc_rxs {
        assert!(rx.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
    }
    for rx in plain_rxs {
        assert!(rx.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
    }
    let s = coord.metrics.snapshot();
    assert_eq!(s.encrypted_completed, 6);
    assert_eq!(s.plain_completed, 6);
    coord.shutdown();
}
