//! Randomized property tests over the CKKS evaluator (E6 / Fig. 1):
//! the homomorphism laws the whole HRF correctness story rests on.

use cryptotree::ckks::evaluator::Evaluator;
use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, KeyGenerator};
use cryptotree::rng::Xoshiro256pp;

struct World {
    ctx: cryptotree::ckks::rns::ContextRef,
    enc: Encoder,
    encryptor: Encryptor,
    decryptor: Decryptor,
    rlk: cryptotree::ckks::keys::RelinKey,
    gk: cryptotree::ckks::keys::GaloisKeys,
    ev: Evaluator,
}

fn world(seed: u64, rotations: &[usize]) -> World {
    let ctx = CkksContext::new(CkksParams::toy());
    let enc = Encoder::new(&ctx);
    let mut kg = KeyGenerator::new(&ctx, seed);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, rotations);
    World {
        ev: Evaluator::new(ctx.clone()),
        encryptor: Encryptor::new(pk, seed + 1),
        decryptor: Decryptor::new(kg.secret_key()),
        rlk,
        gk,
        enc,
        ctx,
    }
}

fn rand_vec(rng: &mut Xoshiro256pp, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

fn assert_close(got: &[f64], want: &[f64], tol: f64, what: &str) {
    for i in 0..want.len() {
        assert!(
            (got[i] - want[i]).abs() < tol,
            "{what}: slot {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

/// (a+b)·c == a·c + b·c under encryption (distributivity).
#[test]
fn distributivity_randomized() {
    let mut w = world(1000, &[]);
    let mut rng = Xoshiro256pp::new(7);
    let n = w.enc.slots();
    for trial in 0..3 {
        let (a, b, c) = (
            rand_vec(&mut rng, n),
            rand_vec(&mut rng, n),
            rand_vec(&mut rng, n),
        );
        let ca = w.encryptor.encrypt_slots(&w.ctx, &w.enc, &a);
        let cb = w.encryptor.encrypt_slots(&w.ctx, &w.enc, &b);
        let cc = w.encryptor.encrypt_slots(&w.ctx, &w.enc, &c);
        // lhs = (a+b)*c
        let sum = w.ev.add(&ca, &cb);
        let mut lhs = w.ev.mul(&sum, &cc, &w.rlk);
        w.ev.rescale(&mut lhs);
        // rhs = a*c + b*c
        let mut ac = w.ev.mul(&ca, &cc, &w.rlk);
        w.ev.rescale(&mut ac);
        let mut bc = w.ev.mul(&cb, &cc, &w.rlk);
        w.ev.rescale(&mut bc);
        bc.scale = ac.scale;
        let rhs = w.ev.add(&ac, &bc);
        let dl = w.decryptor.decrypt_slots(&w.ctx, &w.enc, &lhs);
        let dr = w.decryptor.decrypt_slots(&w.ctx, &w.enc, &rhs);
        assert_close(&dl, &dr, 1e-3, &format!("distributivity trial {trial}"));
    }
}

/// Rotation is additive: rot(a, r1+r2) == rot(rot(a, r1), r2).
#[test]
fn rotation_composition() {
    let mut w = world(2000, &[1, 2, 3]);
    let mut rng = Xoshiro256pp::new(8);
    let n = w.enc.slots();
    let a = rand_vec(&mut rng, n);
    let ca = w.encryptor.encrypt_slots(&w.ctx, &w.enc, &a);
    let r12 = {
        let r1 = w.ev.rotate(&ca, 1, &w.gk);
        w.ev.rotate(&r1, 2, &w.gk)
    };
    let r3 = w.ev.rotate(&ca, 3, &w.gk);
    let d12 = w.decryptor.decrypt_slots(&w.ctx, &w.enc, &r12);
    let d3 = w.decryptor.decrypt_slots(&w.ctx, &w.enc, &r3);
    assert_close(&d12, &d3, 1e-4, "rotation composition");
}

/// Rotation commutes with plaintext multiplication of a rotated mask.
#[test]
fn rotation_mul_commutes() {
    let mut w = world(3000, &[4]);
    let mut rng = Xoshiro256pp::new(9);
    let n = w.enc.slots();
    let a = rand_vec(&mut rng, n);
    let mask = rand_vec(&mut rng, n);
    let ca = w.encryptor.encrypt_slots(&w.ctx, &w.enc, &a);
    // lhs: rot(a) * mask
    let rot = w.ev.rotate(&ca, 4, &w.gk);
    let m_pt = w.ev.encode_for(&w.enc, &mask, &rot, w.ctx.params.scale);
    let mut lhs = w.ev.mul_plain(&rot, &m_pt);
    w.ev.rescale(&mut lhs);
    // rhs: rot(a * rot_right(mask))
    let mask_right: Vec<f64> = (0..n).map(|i| mask[(i + n - 4) % n]).collect();
    let mr_pt = w.ev.encode_for(&w.enc, &mask_right, &ca, w.ctx.params.scale);
    let mut prod = w.ev.mul_plain(&ca, &mr_pt);
    w.ev.rescale(&mut prod);
    let rhs = w.ev.rotate(&prod, 4, &w.gk);
    let dl = w.decryptor.decrypt_slots(&w.ctx, &w.enc, &lhs);
    let dr = w.decryptor.decrypt_slots(&w.ctx, &w.enc, &rhs);
    assert_close(&dl, &dr, 1e-4, "rotate/mul commute");
}

/// Noise stays decodeable across the full depth of the chain.
#[test]
fn deep_mul_chain_preserves_precision() {
    let ctx = CkksContext::new(CkksParams::fast());
    let enc = Encoder::new(&ctx);
    let mut kg = KeyGenerator::new(&ctx, 4000);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let mut encryptor = Encryptor::new(pk, 4001);
    let decryptor = Decryptor::new(kg.secret_key());
    let mut ev = Evaluator::new(ctx.clone());
    let mut rng = Xoshiro256pp::new(10);
    let n = enc.slots();
    let a = rand_vec(&mut rng, n);
    let mut ct = encryptor.encrypt_slots(&ctx, &enc, &a);
    let mut expect = a.clone();
    // Square down the whole chain: values stay in [-1,1].
    for depth in 0..ctx.params.depth() {
        ct = ev.square(&ct, &rlk);
        ev.rescale(&mut ct);
        for e in expect.iter_mut() {
            *e = *e * *e;
        }
        let d = decryptor.decrypt_slots(&ctx, &enc, &ct);
        let max_err = d
            .iter()
            .zip(&expect)
            .map(|(g, e)| (g - e).abs())
            .fold(0.0f64, f64::max);
        assert!(
            max_err < 1e-2,
            "depth {depth}: error {max_err} too large"
        );
    }
    assert_eq!(ct.level, 0);
}

/// Scale tracking: the tracked scale always matches Δ within drift
/// bounds after arbitrary mul/rescale sequences.
#[test]
fn scale_drift_is_bounded() {
    let mut w = world(5000, &[]);
    let mut rng = Xoshiro256pp::new(11);
    let n = w.enc.slots();
    let a = rand_vec(&mut rng, n);
    let mut ct = w.encryptor.encrypt_slots(&w.ctx, &w.enc, &a);
    let delta = w.ctx.params.scale;
    for _ in 0..w.ctx.params.depth() {
        let sq = w.ev.square(&ct, &w.rlk);
        ct = sq;
        w.ev.rescale(&mut ct);
        let drift = (ct.scale / delta).log2().abs();
        assert!(drift < 0.1, "scale drifted {drift} bits from Δ");
    }
}
