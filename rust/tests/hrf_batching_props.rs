//! Sample-group batching properties and the batched HE end-to-end
//! check:
//!
//! 1. **Non-interference** — for random (K, L, C, B) plans, pack B
//!    random samples and fill every slot outside the occupied groups'
//!    used regions with garbage: each sample's scores must equal its
//!    single-sample result exactly (plain slot model) — garbage in
//!    another group's slots must not leak.
//! 2. **Rotation discipline** — every Galois key a batched evaluation
//!    uses is in `rotations_needed_batched(B)`, and no *evaluation*
//!    rotation reads across a group boundary at a slot where the
//!    operand is nonzero.
//! 3. **Batched HE e2e** — a full group of samples packed into one
//!    ciphertext, evaluated once, matches the single-sample plain slot
//!    model within 5e-3 for every sample.
//! 4. **Coordinator wiring** — server-side packing (enc_batch > 1,
//!    folded schedule with slot-addressed `EncScores` responses) and
//!    client-side packed submission both return correct per-sample
//!    scores through the coordinator.
//!
//! Schedule-level properties (bit-identity, key derivation, the exact
//! C·(B−1) rotation saving) live in `tests/schedule_props.rs`.

use cryptotree::ckks::rns::CkksContext;
use cryptotree::ckks::{CkksParams, Decryptor, Encoder, Encryptor, Evaluator, KeyGenerator};
use cryptotree::coordinator::{Coordinator, CoordinatorConfig, SessionManager};
use cryptotree::hrf::client::{reshuffle_and_pack, reshuffle_and_pack_group, HrfClient};
use cryptotree::hrf::{EncRequest, HrfModel, HrfPlan, HrfServer};
use cryptotree::nrf::activation::chebyshev_fit_tanh;
use cryptotree::nrf::{Activation, NeuralForest, NeuralTree};
use cryptotree::rng::Xoshiro256pp;
use std::sync::Arc;

/// A random synthetic NeuralForest with exact (K, L, C) — lets the
/// properties sweep shapes no trained forest would produce.
fn synth_forest(k: usize, l: usize, c: usize, d: usize, rng: &mut Xoshiro256pp) -> NeuralForest {
    let trees = (0..l)
        .map(|_| NeuralTree {
            tau: (0..k - 1).map(|_| rng.next_index(d)).collect(),
            t: (0..k - 1).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            v: (0..k)
                .map(|_| (0..k - 1).map(|_| rng.uniform(-0.25, 0.25)).collect())
                .collect(),
            b: (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect(),
            w: (0..c)
                .map(|_| (0..k).map(|_| rng.uniform(-0.5, 0.5)).collect())
                .collect(),
            beta: (0..c).map(|_| rng.uniform(-0.2, 0.2)).collect(),
            real_leaves: k,
            n_classes: c,
        })
        .collect();
    NeuralForest {
        trees,
        alphas: (0..l).map(|_| rng.uniform(0.1, 1.0)).collect(),
        k,
        n_classes: c,
        activation: Activation::Poly {
            coeffs: chebyshev_fit_tanh(3.0, 4),
        },
    }
}

fn rand_x(d: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..d).map(|_| rng.uniform(0.0, 1.0)).collect()
}

#[test]
fn property_batched_samples_do_not_interfere() {
    let mut rng = Xoshiro256pp::new(4242);
    for case in 0..30 {
        let k = 1usize << (1 + rng.next_index(3)); // 2, 4, 8
        let l = 1 + rng.next_index(6); // 1..6
        let c = 1 + rng.next_index(3); // 1..3
        let d = 4 + rng.next_index(8);
        let used = l * (2 * k - 1);
        // Leave room for at least 2 groups, at most 16.
        let span = used.next_power_of_two();
        let slots = span * (2usize << rng.next_index(3)); // 2, 4, 8 groups
        let nf = synth_forest(k, l, c, d, &mut rng);
        let hm = HrfModel::from_neural_forest(&nf, d, slots)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let p = hm.plan;
        assert!(p.groups >= 2);

        let b = 1 + rng.next_index(p.groups); // 1..=groups samples
        let xs: Vec<Vec<f64>> = (0..b).map(|_| rand_x(d, &mut rng)).collect();
        let singles: Vec<Vec<f64>> = xs
            .iter()
            .map(|x| hm.forward_slots_plain(&reshuffle_and_pack(&hm, x)))
            .collect();

        // Pack the batch, then deliberately poison every slot outside
        // the occupied groups' used regions (unoccupied groups AND the
        // occupied groups' tails).
        let mut packed = reshuffle_and_pack_group(&hm, &xs);
        for g in 0..p.groups {
            let lo = p.group_start(g);
            let start = if g < b { lo + p.used_slots } else { lo };
            for s in packed.iter_mut().take(lo + p.reduce_span).skip(start) {
                *s = rng.uniform(-50.0, 50.0);
            }
        }
        let grouped = hm.forward_slots_plain_groups(&packed);
        for (g, single) in singles.iter().enumerate() {
            for (a, e) in grouped[g].iter().zip(single) {
                assert!(
                    (a - e).abs() < 1e-12,
                    "case {case} (K={k} L={l} C={c} B={b} groups={}): \
                     sample {g} leaked: {:?} vs {single:?}",
                    p.groups,
                    grouped[g]
                );
            }
        }
    }
}

#[test]
fn property_rotations_cover_batched_eval_and_stay_group_local() {
    let mut rng = Xoshiro256pp::new(777);
    for _case in 0..40 {
        let k = 1usize << (1 + rng.next_index(4)); // 2..16
        let l = 1 + rng.next_index(8);
        let c = 1 + rng.next_index(3);
        let used = l * (2 * k - 1);
        let span = used.next_power_of_two();
        let slots = span * (1usize << rng.next_index(4)).max(1); // 1..8 groups
        let plan = HrfPlan::new(k, l, c, 8, slots).unwrap();
        let b = 1 + rng.next_index(plan.groups);
        let have = plan.rotations_needed_batched(b);

        // (a) Every rotation the batched protocol performs is covered:
        // Algorithm 1 steps, the group-local reduction's power-of-two
        // steps, and each occupied group's placement + extraction.
        for j in 1..k {
            assert!(have.contains(&j), "missing Alg1 step {j}");
        }
        let mut step = 1usize;
        while step < plan.reduce_span {
            assert!(have.contains(&step), "missing reduction step {step}");
            step <<= 1;
        }
        for g in 1..b {
            assert!(
                have.contains(&(g * plan.reduce_span)),
                "missing extraction step for group {g}"
            );
            assert!(
                have.contains(&(plan.slots - g * plan.reduce_span)),
                "missing placement step for group {g}"
            );
        }

        // (b) No evaluation rotation crosses a group boundary: every
        // step is below the group span, and Algorithm 1 windows stay
        // inside the group wherever a diagonal operand is nonzero
        // (nonzero entries live in the first K slots of each block).
        for &r in &plan.eval_rotations() {
            assert!(r < plan.reduce_span, "eval step {r} spans a group");
        }
        for j in 1..k {
            for li in 0..l {
                let last_read = plan.block_start(li) + (k - 1) + j;
                assert!(
                    last_read < plan.reduce_span,
                    "Alg1 step {j} reads across the group boundary from tree {li}"
                );
            }
        }
    }
}

/// Full group of samples in one ciphertext: one homomorphic
/// evaluation, every sample's decrypted scores within 5e-3 of the
/// single-sample plain slot model.
#[test]
fn batched_he_eval_matches_plain_per_sample() {
    let mut rng = Xoshiro256pp::new(91);
    let d = 10;
    // K=8, L=6 -> block 15, used 90, span 128 -> 32 groups on N=8192.
    let nf = synth_forest(8, 6, 2, d, &mut rng);
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;
    let b = plan.groups; // a FULL group
    assert!(b >= 2, "full-group test needs multiple groups");

    let mut kg = KeyGenerator::new(&ctx, 92);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(b));
    let mut client = HrfClient::new(Encryptor::new(pk, 93), Decryptor::new(kg.secret_key()));
    let server = HrfServer::new(hm);
    let mut ev = Evaluator::new(ctx.clone());

    let xs: Vec<Vec<f64>> = (0..b).map(|_| rand_x(d, &mut rng)).collect();
    let ct = client.encrypt_batch(&ctx, &enc, &server.model, &xs);
    let outs = server
        .execute(&mut ev, &enc, &EncRequest::single(&ct), &rlk, &gk)
        .into_class_scores();
    let results = client.decrypt_scores_batch(&ctx, &enc, &server.model, &outs, b);
    assert_eq!(results.len(), b);
    for (g, ((scores, _), x)) in results.iter().zip(&xs).enumerate() {
        let expect = server
            .model
            .forward_slots_plain(&reshuffle_and_pack(&server.model, x));
        for (s, e) in scores.iter().zip(&expect) {
            assert!(
                (s - e).abs() < 5e-3,
                "sample {g}/{b}: HE {scores:?} vs plain {expect:?}"
            );
        }
    }
}

/// Server-side packing: B fresh single-sample ciphertexts combined
/// with `pack_group`, evaluated once, extracted back to slot 0 — each
/// response must match its own plain result (and differ across
/// distinct samples).
#[test]
fn server_side_pack_group_matches_individual_evals() {
    let mut rng = Xoshiro256pp::new(555);
    let d = 10;
    let nf = synth_forest(8, 6, 2, d, &mut rng);
    let params = CkksParams::fast();
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;
    let b = 3usize.min(plan.groups);

    let mut kg = KeyGenerator::new(&ctx, 556);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(b));
    let mut client = HrfClient::new(Encryptor::new(pk, 557), Decryptor::new(kg.secret_key()));
    let server = HrfServer::new(hm);
    assert!(server.can_batch(&gk, b));
    let mut ev = Evaluator::new(ctx.clone());

    let xs: Vec<Vec<f64>> = (0..b).map(|_| rand_x(d, &mut rng)).collect();
    let cts: Vec<_> = xs
        .iter()
        .map(|x| client.encrypt_input(&ctx, &enc, &server.model, x))
        .collect();
    let per_sample = server
        .execute(&mut ev, &enc, &EncRequest::group_slot0(&cts), &rlk, &gk)
        .into_per_sample();
    assert_eq!(per_sample.len(), b);
    for (g, (outs, x)) in per_sample.iter().zip(&xs).enumerate() {
        let (scores, _) = client.decrypt_scores(&ctx, &enc, outs);
        let expect = server
            .model
            .forward_slots_plain(&reshuffle_and_pack(&server.model, x));
        for (s, e) in scores.iter().zip(&expect) {
            assert!(
                (s - e).abs() < 5e-3,
                "sample {g}: packed-eval {scores:?} vs plain {expect:?}"
            );
        }
    }
}

/// The coordinator's encrypted path with enc_batch > 1: single-sample
/// submissions are transparently packed, every caller still receives
/// its own correct scores, and the batch metrics record the packing.
#[test]
fn coordinator_enc_batching_end_to_end() {
    let mut rng = Xoshiro256pp::new(31);
    let d = 8;
    // Identity activation keeps the depth-4 budget of the cheap ring.
    let mut nf = synth_forest(4, 4, 2, d, &mut rng);
    nf.activation = Activation::Poly {
        coeffs: vec![0.0, 1.0],
    };
    let params = Arc::new(CkksParams::build("enc-batch-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;
    let enc_batch = 4usize.min(plan.groups);
    assert!(enc_batch >= 2);

    let mut kg = KeyGenerator::new(&ctx, 32);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed_batched(enc_batch));
    let mut client = HrfClient::new(Encryptor::new(pk, 33), Decryptor::new(kg.secret_key()));
    let server = Arc::new(HrfServer::new(hm));
    let sessions = Arc::new(SessionManager::new());
    let sid = sessions.register(rlk, gk);
    let coord = Coordinator::start(
        CoordinatorConfig {
            workers: 1,
            enc_batch,
            batch_delay: std::time::Duration::from_millis(20),
            // This test asserts aggregation under a burst, so pin the
            // idle grace to the full window (adaptive idle-flush off).
            idle_flush: std::time::Duration::from_millis(20),
            ..Default::default()
        },
        ctx.clone(),
        server.clone(),
        sessions,
        None,
    );

    // Burst of 2×enc_batch single-sample requests from one session.
    // Encrypt everything first so the submissions land within one
    // batch window.
    let n_req = 2 * enc_batch;
    let xs: Vec<Vec<f64>> = (0..n_req).map(|_| rand_x(d, &mut rng)).collect();
    let cts: Vec<_> = xs
        .iter()
        .map(|x| client.encrypt_input(&ctx, &enc, &server.model, x))
        .collect();
    let rxs: Vec<_> = cts
        .into_iter()
        .map(|ct| coord.submit_encrypted(sid, ct).expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let outs = rx.recv().unwrap().expect("batched eval");
        // Folded batched responses carry the score slot; single /
        // fallback responses use slot 0 — decrypt_response handles
        // both.
        let (scores, _) = client.decrypt_response(&ctx, &enc, &outs);
        let expect = server
            .model
            .forward_slots_plain(&reshuffle_and_pack(&server.model, &xs[i]));
        for (s, e) in scores.iter().zip(&expect) {
            assert!(
                (s - e).abs() < 5e-3,
                "request {i}: coordinator batched path {scores:?} vs plain {expect:?}"
            );
        }
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.encrypted_completed, n_req as u64);
    assert!(snap.enc_batches_flushed >= 1, "no encrypted group flushed");
    assert!(
        snap.mean_enc_batch_fill > 1.0,
        "encrypted batching never aggregated (fill {})",
        snap.mean_enc_batch_fill
    );
    coord.shutdown();
}

/// Client-side packed submission through the coordinator: one
/// ciphertext carrying several samples, unpacked with
/// `decrypt_scores_batch`.
#[test]
fn coordinator_accepts_client_packed_groups() {
    let mut rng = Xoshiro256pp::new(131);
    let d = 8;
    let mut nf = synth_forest(4, 4, 2, d, &mut rng);
    nf.activation = Activation::Poly {
        coeffs: vec![0.0, 1.0],
    };
    let params = Arc::new(CkksParams::build("packed-n4096-d4", 4096, 60, 40, 4, 3.2));
    let ctx = CkksContext::new(params.clone());
    let enc = Encoder::new(&ctx);
    let hm = HrfModel::from_neural_forest(&nf, d, params.slots()).unwrap();
    let plan = hm.plan;
    let b = 3usize.min(plan.groups);

    let mut kg = KeyGenerator::new(&ctx, 132);
    let pk = kg.gen_public_key(&ctx);
    let rlk = kg.gen_relin_key(&ctx);
    let gk = kg.gen_galois_keys(&ctx, &plan.rotations_needed());
    let mut client = HrfClient::new(Encryptor::new(pk, 133), Decryptor::new(kg.secret_key()));
    let server = Arc::new(HrfServer::new(hm));
    let sessions = Arc::new(SessionManager::new());
    let sid = sessions.register(rlk, gk);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        ctx.clone(),
        server.clone(),
        sessions,
        None,
    );

    let xs: Vec<Vec<f64>> = (0..b).map(|_| rand_x(d, &mut rng)).collect();
    let ct = client.encrypt_batch(&ctx, &enc, &server.model, &xs);
    let rx = coord.submit_encrypted_packed(sid, ct, b).expect("submit");
    let outs = rx.recv().unwrap().expect("packed eval");
    let results = client.decrypt_scores_batch(&ctx, &enc, &server.model, &outs.scores, b);
    for (g, ((scores, _), x)) in results.iter().zip(&xs).enumerate() {
        let expect = server
            .model
            .forward_slots_plain(&reshuffle_and_pack(&server.model, x));
        for (s, e) in scores.iter().zip(&expect) {
            assert!(
                (s - e).abs() < 5e-3,
                "packed sample {g}: {scores:?} vs plain {expect:?}"
            );
        }
    }
    assert_eq!(coord.metrics.snapshot().encrypted_completed, b as u64);
    coord.shutdown();
}
